//! Paged KV cache with block tables (the vLLM/FlashInfer storage model,
//! built as a substrate for the serving engine).
//!
//! Storage unit is a **page** of `page_tokens` tokens holding all layers
//! and heads: `[layers, heads, page_tokens, head_dim]` f32, one buffer for
//! K and one for V. Sequences own ordered page lists; the engine gathers
//! a sequence's pages into the contiguous `[l, b, h, ctx_bucket, dh]`
//! views the decode artifact consumes (the CPU-PJRT analogue of the
//! paper's constant-stride tensor requirement, §IV-C).

use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

use super::request::RequestId;

/// Paged K/V storage for many sequences.
pub struct PagedKvCache {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub page_tokens: usize,
    k_pages: Vec<Vec<f32>>,
    v_pages: Vec<Vec<f32>>,
    free: Vec<usize>,
    seqs: HashMap<RequestId, SeqEntry>,
}

struct SeqEntry {
    pages: Vec<usize>,
    len: usize,
}

impl PagedKvCache {
    /// Allocate a cache with a fixed budget of `num_pages` pages.
    pub fn new(
        layers: usize,
        heads: usize,
        head_dim: usize,
        page_tokens: usize,
        num_pages: usize,
    ) -> PagedKvCache {
        let page_elems = layers * heads * page_tokens * head_dim;
        PagedKvCache {
            layers,
            heads,
            head_dim,
            page_tokens,
            k_pages: (0..num_pages).map(|_| vec![0.0; page_elems]).collect(),
            v_pages: (0..num_pages).map(|_| vec![0.0; page_elems]).collect(),
            free: (0..num_pages).rev().collect(),
            seqs: HashMap::new(),
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn total_pages(&self) -> usize {
        self.k_pages.len()
    }

    pub fn seq_len(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Whether a sequence of `tokens` tokens can currently be admitted.
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    /// Register a new sequence and copy in its prefill K/V
    /// (`[layers, heads, len, head_dim]` row-major per tensor).
    pub fn insert_seq(&mut self, id: RequestId, k: &[f32], v: &[f32], len: usize) -> Result<()> {
        ensure!(!self.seqs.contains_key(&id), "sequence {id} already cached");
        let plane = self.heads * self.head_dim;
        ensure!(k.len() == self.layers * plane * len, "prefill k size");
        ensure!(v.len() == k.len(), "prefill v size");
        let need = self.pages_for(len.max(1));
        if need > self.free.len() {
            bail!("cache full: need {need} pages, {} free", self.free.len());
        }
        let pages: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        let mut entry = SeqEntry { pages, len: 0 };
        let (heads, dh) = (self.heads, self.head_dim);
        for t in 0..len {
            self.write_token(&mut entry, t, |l, h| {
                let base = (l * heads + h) * len * dh + t * dh;
                (&k[base..base + dh], &v[base..base + dh])
            });
        }
        entry.len = len;
        self.seqs.insert(id, entry);
        Ok(())
    }

    /// Append one token's K/V rows (`[layers, heads, head_dim]` each).
    pub fn append_token(&mut self, id: RequestId, k: &[f32], v: &[f32]) -> Result<()> {
        let plane = self.layers * self.heads * self.head_dim;
        ensure!(k.len() == plane, "append k size");
        ensure!(v.len() == plane, "append v size");
        let mut entry = self.seqs.remove(&id).ok_or_else(|| {
            anyhow::anyhow!("sequence {id} not cached")
        })?;
        let t = entry.len;
        if t >= entry.pages.len() * self.page_tokens {
            if self.free.is_empty() {
                self.seqs.insert(id, entry);
                bail!("cache full appending to sequence {id}");
            }
            let p = self.free.pop().unwrap();
            entry.pages.push(p);
        }
        let (heads, dh) = (self.heads, self.head_dim);
        self.write_token(&mut entry, t, |l, h| {
            let base = (l * heads + h) * dh;
            (&k[base..base + dh], &v[base..base + dh])
        });
        entry.len = t + 1;
        self.seqs.insert(id, entry);
        Ok(())
    }

    fn write_token<'a>(
        &mut self,
        entry: &mut SeqEntry,
        t: usize,
        src: impl Fn(usize, usize) -> (&'a [f32], &'a [f32]),
    ) {
        let page = entry.pages[t / self.page_tokens];
        let slot = t % self.page_tokens;
        let dh = self.head_dim;
        for l in 0..self.layers {
            for h in 0..self.heads {
                let off = ((l * self.heads + h) * self.page_tokens + slot) * dh;
                let (ks, vs) = src(l, h);
                self.k_pages[page][off..off + dh].copy_from_slice(ks);
                self.v_pages[page][off..off + dh].copy_from_slice(vs);
            }
        }
    }

    /// Gather a batch of sequences into contiguous decode-artifact views
    /// `[layers, batch, heads, ctx_bucket, head_dim]` (zero-padded).
    /// `slots[i] = Some(request)` maps batch lane `i` to a sequence.
    pub fn gather(
        &self,
        slots: &[Option<RequestId>],
        ctx_bucket: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let b = slots.len();
        let dh = self.head_dim;
        let expect = self.layers * b * self.heads * ctx_bucket * dh;
        ensure!(k_out.len() == expect, "k_out size");
        ensure!(v_out.len() == expect, "v_out size");
        k_out.fill(0.0);
        v_out.fill(0.0);
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = slot else { continue };
            let entry = self
                .seqs
                .get(id)
                .ok_or_else(|| anyhow::anyhow!("sequence {id} not cached"))?;
            ensure!(entry.len <= ctx_bucket, "sequence longer than ctx bucket");
            for l in 0..self.layers {
                for h in 0..self.heads {
                    let dst_base =
                        (((l * b) + bi) * self.heads + h) * ctx_bucket * dh;
                    // copy page by page
                    for (pi, &page) in entry.pages.iter().enumerate() {
                        let t0 = pi * self.page_tokens;
                        if t0 >= entry.len {
                            break;
                        }
                        let count = self.page_tokens.min(entry.len - t0);
                        let src_base =
                            ((l * self.heads + h) * self.page_tokens) * dh;
                        let dst = dst_base + t0 * dh;
                        k_out[dst..dst + count * dh].copy_from_slice(
                            &self.k_pages[page][src_base..src_base + count * dh],
                        );
                        v_out[dst..dst + count * dh].copy_from_slice(
                            &self.v_pages[page][src_base..src_base + count * dh],
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Release a sequence's pages.
    pub fn free_seq(&mut self, id: RequestId) {
        if let Some(entry) = self.seqs.remove(&id) {
            self.free.extend(entry.pages);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cache() -> PagedKvCache {
        PagedKvCache::new(2, 3, 4, 8, 16)
    }

    fn rows(rng: &mut Rng, layers: usize, heads: usize, len: usize, dh: usize) -> Vec<f32> {
        rng.normal_vec(layers * heads * len * dh)
    }

    #[test]
    fn insert_gather_round_trip() {
        let mut c = cache();
        let mut rng = Rng::new(1);
        let len = 13; // crosses a page boundary (page=8)
        let k = rows(&mut rng, 2, 3, len, 4);
        let v = rows(&mut rng, 2, 3, len, 4);
        c.insert_seq(7, &k, &v, len).unwrap();
        assert_eq!(c.seq_len(7), Some(13));
        assert_eq!(c.free_pages(), 16 - 2);

        let ctx = 16;
        let mut ko = vec![0.0; 2 * 1 * 3 * ctx * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(7)], ctx, &mut ko, &mut vo).unwrap();
        // spot-check token t=9, layer 1, head 2
        let (l, h, t) = (1usize, 2usize, 9usize);
        let src = (l * 3 + h) * len * 4 + t * 4;
        let dst = ((l * 1) * 3 + h) * ctx * 4 + t * 4;
        assert_eq!(&ko[dst..dst + 4], &k[src..src + 4]);
        assert_eq!(&vo[dst..dst + 4], &v[src..src + 4]);
        // padding is zero
        let pad = ((0 * 1) * 3 + 0) * ctx * 4 + 15 * 4;
        assert_eq!(&ko[pad..pad + 4], &[0.0; 4]);
    }

    #[test]
    fn append_token_and_page_growth() {
        let mut c = cache();
        let mut rng = Rng::new(2);
        let k = rows(&mut rng, 2, 3, 8, 4);
        let v = rows(&mut rng, 2, 3, 8, 4);
        c.insert_seq(1, &k, &v, 8).unwrap(); // exactly one page
        assert_eq!(c.free_pages(), 15);
        let nk = rng.normal_vec(2 * 3 * 4);
        let nv = rng.normal_vec(2 * 3 * 4);
        c.append_token(1, &nk, &nv).unwrap(); // forces a second page
        assert_eq!(c.free_pages(), 14);
        assert_eq!(c.seq_len(1), Some(9));

        let mut ko = vec![0.0; 2 * 1 * 3 * 16 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(1)], 16, &mut ko, &mut vo).unwrap();
        // token 8 row for layer 0 head 1
        let dst = ((0 * 1) * 3 + 1) * 16 * 4 + 8 * 4;
        assert_eq!(&ko[dst..dst + 4], &nk[4..8]);
    }

    #[test]
    fn free_seq_returns_pages() {
        let mut c = cache();
        let mut rng = Rng::new(3);
        let k = rows(&mut rng, 2, 3, 20, 4);
        let v = rows(&mut rng, 2, 3, 20, 4);
        c.insert_seq(5, &k, &v, 20).unwrap();
        let used = 16 - c.free_pages();
        assert_eq!(used, 3); // ceil(20/8)
        c.free_seq(5);
        assert_eq!(c.free_pages(), 16);
        assert_eq!(c.seq_len(5), None);
    }

    #[test]
    fn admission_control() {
        let mut c = cache();
        assert!(c.can_admit(16 * 8));
        assert!(!c.can_admit(16 * 8 + 1));
        let mut rng = Rng::new(4);
        let k = rows(&mut rng, 2, 3, 100, 4);
        let v = rows(&mut rng, 2, 3, 100, 4);
        c.insert_seq(1, &k, &v, 100).unwrap(); // 13 pages
        assert!(!c.can_admit(8 * 4)); // only 3 pages left
        let err = c.insert_seq(2, &k, &v, 100).unwrap_err();
        assert!(err.to_string().contains("cache full"));
    }

    #[test]
    fn cache_full_append_is_recoverable() {
        let mut c = PagedKvCache::new(1, 1, 2, 2, 1);
        c.insert_seq(1, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2)
            .unwrap();
        let err = c.append_token(1, &[9.0, 9.0], &[9.0, 9.0]).unwrap_err();
        assert!(err.to_string().contains("cache full"));
        // sequence still intact
        assert_eq!(c.seq_len(1), Some(2));
    }

    #[test]
    fn gather_multi_batch_lanes() {
        let mut c = cache();
        let mut rng = Rng::new(5);
        for id in 0..3u64 {
            let len = 4 + id as usize;
            let k = rows(&mut rng, 2, 3, len, 4);
            let v = rows(&mut rng, 2, 3, len, 4);
            c.insert_seq(id, &k, &v, len).unwrap();
        }
        let mut ko = vec![0.0; 2 * 4 * 3 * 8 * 4];
        let mut vo = vec![0.0; ko.len()];
        c.gather(&[Some(2), None, Some(0), Some(1)], 8, &mut ko, &mut vo)
            .unwrap();
        // lane 1 is empty -> zeros
        let lane1 = ((0 * 4 + 1) * 3) * 8 * 4;
        assert!(ko[lane1..lane1 + 8 * 4].iter().all(|&x| x == 0.0));
    }
}
