//! Continuous (iteration-level) batching, after Orca [41]: a fixed number
//! of engine slots; whenever one frees, the next waiting request is
//! admitted at the following step boundary — no batch-completion barrier.

use std::cell::Cell;
use std::collections::VecDeque;

use super::request::{Request, RequestId};

/// Waiting-queue + slot bookkeeping.
pub struct ContinuousBatcher {
    slots: Vec<Option<RequestId>>,
    waiting: VecDeque<Request>,
    /// High-water mark of the waiting queue — the congestion gauge the
    /// observability snapshot exports. A `Cell` so the snapshot path
    /// (`&self`) can take-and-reset it with interval semantics.
    peak_waiting: Cell<usize>,
}

impl ContinuousBatcher {
    pub fn new(num_slots: usize) -> ContinuousBatcher {
        assert!(num_slots >= 1);
        ContinuousBatcher {
            slots: vec![None; num_slots],
            waiting: VecDeque::new(),
            peak_waiting: Cell::new(0),
        }
    }

    pub fn enqueue(&mut self, r: Request) {
        self.waiting.push_back(r);
        self.peak_waiting.set(self.peak_waiting.get().max(self.waiting.len()));
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Deepest the waiting queue has been since the last
    /// [`ContinuousBatcher::take_peak_waiting`] (monotonic in between).
    pub fn peak_waiting(&self) -> usize {
        self.peak_waiting.get()
    }

    /// Read the watermark and reset it to the *current* queue depth, so
    /// consecutive observability snapshots report per-interval peaks
    /// instead of a whole-lifetime maximum (a burst at boot no longer
    /// pins the gauge forever). Resetting to the live depth — not zero —
    /// keeps a standing queue visible in every interval.
    pub fn take_peak_waiting(&self) -> usize {
        let peak = self.peak_waiting.get();
        self.peak_waiting.set(self.waiting.len());
        peak
    }

    pub fn active_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_slots(&self) -> usize {
        self.slots.len() - self.active_len()
    }

    pub fn slots(&self) -> &[Option<RequestId>] {
        &self.slots
    }

    pub fn is_idle(&self) -> bool {
        self.active_len() == 0 && self.waiting.is_empty()
    }

    /// The request next in line for admission (FCFS head), if any.
    pub fn peek_waiting(&self) -> Option<&Request> {
        self.waiting.front()
    }

    /// Admit waiting requests into free slots, gated by `admit` (capacity
    /// check, e.g. KV-cache pages). Returns `(slot, request)` pairs in
    /// admission order.
    pub fn admit(&mut self, mut can_admit: impl FnMut(&Request) -> bool) -> Vec<(usize, Request)> {
        let mut admitted = Vec::new();
        for si in 0..self.slots.len() {
            if self.slots[si].is_some() {
                continue;
            }
            // FCFS: only the queue head may be admitted (no starvation /
            // reordering of large requests).
            let Some(front) = self.waiting.front() else { break };
            if !can_admit(front) {
                break;
            }
            let r = self.waiting.pop_front().unwrap();
            self.slots[si] = Some(r.id);
            admitted.push((si, r));
        }
        admitted
    }

    /// Place an engine-created sequence (a fork sibling) directly into a
    /// free slot, bypassing the FCFS waiting queue — siblings must join
    /// their family's decode wave immediately, not queue behind unrelated
    /// requests. Returns the slot, or `None` when every slot is taken.
    pub fn occupy(&mut self, id: RequestId) -> Option<usize> {
        let si = self.slots.iter().position(|s| s.is_none())?;
        self.slots[si] = Some(id);
        Some(si)
    }

    /// Free the slot owning `id` (request finished or evicted).
    pub fn release(&mut self, id: RequestId) {
        for s in &mut self.slots {
            if *s == Some(id) {
                *s = None;
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn fcfs_admission_into_free_slots() {
        let mut b = ContinuousBatcher::new(2);
        b.enqueue(req(1));
        b.enqueue(req(2));
        b.enqueue(req(3));
        let adm = b.admit(|_| true);
        assert_eq!(adm.iter().map(|(s, r)| (*s, r.id)).collect::<Vec<_>>(), vec![(0, 1), (1, 2)]);
        assert_eq!(b.waiting_len(), 1);
        assert_eq!(b.active_len(), 2);
    }

    #[test]
    fn release_then_admit_next() {
        let mut b = ContinuousBatcher::new(1);
        b.enqueue(req(1));
        b.enqueue(req(2));
        assert_eq!(b.admit(|_| true).len(), 1);
        assert_eq!(b.admit(|_| true).len(), 0); // no free slot
        b.release(1);
        let adm = b.admit(|_| true);
        assert_eq!(adm[0].1.id, 2);
        assert_eq!(adm[0].0, 0); // reused slot 0
    }

    #[test]
    fn admission_gate_blocks_head_of_line() {
        let mut b = ContinuousBatcher::new(2);
        b.enqueue(req(1));
        b.enqueue(req(2));
        // capacity check rejects everything
        assert!(b.admit(|_| false).is_empty());
        assert_eq!(b.waiting_len(), 2);
        // head-of-line blocking is deliberate (FCFS): a gate that accepts
        // only id 2 still admits nothing
        assert!(b.admit(|r| r.id == 2).is_empty());
    }

    #[test]
    fn peek_waiting_sees_fcfs_head() {
        let mut b = ContinuousBatcher::new(1);
        assert!(b.peek_waiting().is_none());
        b.enqueue(req(3));
        b.enqueue(req(4));
        assert_eq!(b.peek_waiting().unwrap().id, 3);
        b.admit(|_| true);
        assert_eq!(b.peek_waiting().unwrap().id, 4);
    }

    #[test]
    fn occupy_fills_free_slots_and_respects_capacity() {
        let mut b = ContinuousBatcher::new(2);
        b.enqueue(req(1));
        b.admit(|_| true);
        // A fork sibling takes the remaining slot directly.
        assert_eq!(b.occupy(10), Some(1));
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.occupy(11), None, "no slot left");
        // Releasing the sibling frees its slot like any request.
        b.release(10);
        assert_eq!(b.occupy(11), Some(1));
        // The waiting queue is untouched by occupy.
        b.enqueue(req(2));
        assert_eq!(b.waiting_len(), 1);
    }

    #[test]
    fn peak_waiting_is_a_monotonic_watermark() {
        let mut b = ContinuousBatcher::new(1);
        assert_eq!(b.peak_waiting(), 0);
        b.enqueue(req(1));
        b.enqueue(req(2));
        b.enqueue(req(3));
        assert_eq!(b.peak_waiting(), 3);
        // Draining the queue never lowers the watermark.
        b.admit(|_| true);
        assert_eq!(b.waiting_len(), 2);
        assert_eq!(b.peak_waiting(), 3);
        b.release(1);
        b.admit(|_| true);
        assert_eq!(b.peak_waiting(), 3);
        // A deeper wave raises it again.
        for i in 4..8 {
            b.enqueue(req(i));
        }
        assert_eq!(b.peak_waiting(), 5);
    }

    #[test]
    fn take_peak_waiting_resets_to_the_live_depth() {
        let mut b = ContinuousBatcher::new(1);
        for i in 1..=3 {
            b.enqueue(req(i));
        }
        b.admit(|_| true); // depth 3 -> 2
        // First interval saw the burst.
        assert_eq!(b.take_peak_waiting(), 3);
        // The reset lands on the live depth, not zero: a standing queue
        // stays visible in the next interval even with no new arrivals.
        assert_eq!(b.peak_waiting(), 2);
        assert_eq!(b.take_peak_waiting(), 2);
        // Draining between takes lowers the *next* interval's floor...
        b.release(1);
        b.admit(|_| true);
        assert_eq!(b.waiting_len(), 1);
        // ...but never an already-observed peak: the take still reports
        // the depth at reset time, then re-floors at the live depth.
        assert_eq!(b.take_peak_waiting(), 2);
        assert_eq!(b.take_peak_waiting(), 1);
        // A new wave raises the interval peak from that floor.
        for i in 4..6 {
            b.enqueue(req(i));
        }
        assert_eq!(b.take_peak_waiting(), 3);
    }

    #[test]
    fn idle_detection() {
        let mut b = ContinuousBatcher::new(1);
        assert!(b.is_idle());
        b.enqueue(req(1));
        assert!(!b.is_idle());
        b.admit(|_| true);
        assert!(!b.is_idle());
        b.release(1);
        assert!(b.is_idle());
    }
}
