//! Engine metrics: step counters, token throughput, latency percentiles,
//! prefix-cache accounting, and the per-step LeanAttention-vs-FlashDecoding
//! hardware projection the engine records (linking the serving loop back
//! to the paper's contribution).
//!
//! Latency series (`step_us`, `prefill_us`, the projection series) are
//! [`LogHistogram`]s, not raw `Vec<f64>`s — memory stays fixed on a
//! long-running engine while mean/min/max stay exact and percentiles
//! stay within one bucket width (~9%). Everything the module exports is
//! enumerated in [`DOCUMENTED_METRICS`] and serialized through one
//! [`MetricsSnapshot`] ([`Metrics::snapshot`]), so the Prometheus and
//! JSON exporters can never disagree about which counters exist.

use crate::obs::attrib::WorkAccounting;
use crate::obs::hist::LogHistogram;
use crate::obs::snapshot::MetricsSnapshot;
use crate::spec::SpecStats;
use crate::util::stats::Summary;

/// Prefix-cache (radix index) counters.
#[derive(Clone, Debug, Default)]
pub struct PrefixCacheStats {
    /// Index probes — admission-gate peeks (including requests that were
    /// rejected or left queued), eviction-pass peeks, and the post-prefill
    /// registration lookups. A single request can account for several
    /// probes, so this counts actual index traffic, not admitted prompts.
    pub lookups: usize,
    /// Admitted prompts that matched at least one full page.
    pub hits: usize,
    /// Prompt tokens served from cached prefix pages.
    pub tokens_matched: usize,
    /// Page references taken on cached prefix pages by admitted sequences.
    pub pages_shared: usize,
    /// K+V bytes the shared pages would otherwise have duplicated.
    pub kv_bytes_deduped: u64,
    /// Index pages evicted under cache pressure.
    pub evicted_pages: usize,
    /// Copy-on-write page clones performed by the cache.
    pub cow_copies: usize,
}

impl PrefixCacheStats {
    /// Fraction of index probes that led to an admitted prompt reusing at
    /// least one cached prefix page.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    fn merge(&mut self, o: &PrefixCacheStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.tokens_matched += o.tokens_matched;
        self.pages_shared += o.pages_shared;
        self.kv_bytes_deduped += o.kv_bytes_deduped;
        self.evicted_pages += o.evicted_pages;
        self.cow_copies += o.cow_copies;
    }
}

/// Sparse page-selection counters (long-context decode).
#[derive(Clone, Debug, Default)]
pub struct SparseStats {
    /// Decode steps that gathered through the selected-page sparse path.
    pub selection_steps: usize,
    /// Lanes whose context pages were actually scored (dense-threshold
    /// bypasses excluded).
    pub lanes_scored: usize,
    /// Context pages considered across scored lanes.
    pub pages_total: usize,
    /// Pages the selections kept — what the step actually scanned.
    pub pages_scanned: usize,
    /// K+V bytes a dense gather would have materialized on sparse steps
    /// (per lane, full context).
    pub gather_bytes_dense: u64,
    /// K+V bytes of the selected pages, counted per lane so the ratio
    /// against `gather_bytes_dense` isolates pure selection — cascade
    /// dedup of shared sink runs (which the dense path enjoys too) is
    /// reported by the cascade gather counters, not here.
    pub gather_bytes_sparse: u64,
    /// Sum of per-lane score-mass coverage: the softmax-weighted share
    /// of page upper-bound scores the selection retained (a proxy for
    /// attention-mass coverage).
    pub coverage_sum: f64,
    /// Lanes contributing to `coverage_sum`.
    pub coverage_samples: usize,
}

impl SparseStats {
    /// Fold one scored lane's selection into the counters — the single
    /// accounting both the engine and the bench harness use.
    pub fn record_scored_lane(&mut self, scores: &[f32], selected: &[usize]) {
        self.lanes_scored += 1;
        self.pages_total += scores.len();
        self.pages_scanned += selected.len();
        self.coverage_sum += crate::sparse::score_coverage(scores, selected);
        self.coverage_samples += 1;
    }

    /// Fraction of considered pages the selections kept.
    pub fn scan_fraction(&self) -> f64 {
        if self.pages_total == 0 {
            1.0
        } else {
            self.pages_scanned as f64 / self.pages_total as f64
        }
    }

    /// Mean score-mass coverage across scored lanes.
    pub fn mean_coverage(&self) -> f64 {
        if self.coverage_samples == 0 {
            1.0
        } else {
            self.coverage_sum / self.coverage_samples as f64
        }
    }

    fn merge(&mut self, o: &SparseStats) {
        self.selection_steps += o.selection_steps;
        self.lanes_scored += o.lanes_scored;
        self.pages_total += o.pages_total;
        self.pages_scanned += o.pages_scanned;
        self.gather_bytes_dense += o.gather_bytes_dense;
        self.gather_bytes_sparse += o.gather_bytes_sparse;
        self.coverage_sum += o.coverage_sum;
        self.coverage_samples += o.coverage_samples;
    }
}

/// Grouped-query attention (GQA/MQA) plane gauges: the engine's KV-head
/// configuration plus the gather-byte shrink the grouped plane delivers.
#[derive(Clone, Debug, Default)]
pub struct GqaStats {
    /// KV heads per layer — the granularity of the paged cache and of
    /// every decode gather (0 until an engine configures it).
    pub kv_heads: usize,
    /// Query heads sharing each KV head (`h / h_kv`; 1 means ungrouped,
    /// 0 until configured).
    pub group_size: usize,
    /// K+V bytes decode gathers actually moved at kv-head granularity.
    pub gather_bytes_grouped: u64,
    /// K+V bytes the same gathers would have moved with one KV head per
    /// query head (grouped bytes × group size) — the dense baseline the
    /// `h/h_kv` shrink is measured against.
    pub gather_bytes_dense: u64,
}

impl GqaStats {
    /// Fold one decode gather's byte count in; the dense-equivalent
    /// baseline scales by the configured group size.
    pub fn record_gather(&mut self, grouped_bytes: u64) {
        self.gather_bytes_grouped += grouped_bytes;
        self.gather_bytes_dense += grouped_bytes * self.group_size.max(1) as u64;
    }

    fn merge(&mut self, o: &GqaStats) {
        // Shape gauges, not counters: replicas of one deployment share a
        // model, so keep whichever side is configured.
        self.kv_heads = self.kv_heads.max(o.kv_heads);
        self.group_size = self.group_size.max(o.group_size);
        self.gather_bytes_grouped += o.gather_bytes_grouped;
        self.gather_bytes_dense += o.gather_bytes_dense;
    }
}

/// Exact work-attribution totals over served decode steps — the
/// engine-side end of the perf-attribution plane. Gather bytes are
/// folded in by the gather path itself (which knows sparse/shared
/// dedup); tile/flop/fold totals by the per-step plan accounting. Both
/// go through the same [`crate::obs::attrib`] functions the simulator
/// and bench reports price, so metered work and modeled work cannot
/// drift by construction (`tests/attrib_props.rs` pins the byte
/// counters bit-exactly against the cache's own accounting).
#[derive(Clone, Debug, Default)]
pub struct AttribStats {
    /// K+V bytes decode gathers materialized, attrib-accounted.
    pub gather_bytes: u64,
    /// LeanTiles the per-step decode plans visited.
    pub tiles: u64,
    /// Online-softmax flops those plans performed.
    pub softmax_flops: u64,
    /// Rescale folds (Alg 2 L24-39 reductions) those plans performed.
    pub rescale_folds: u64,
}

impl AttribStats {
    /// Fold one step's planned work in. Bytes are *not* taken from the
    /// plan — the gather path records them, because only it knows how
    /// much the sparse/shared paths deduplicated.
    pub fn record_plan(&mut self, w: &WorkAccounting) {
        self.tiles += w.tiles;
        self.softmax_flops += w.softmax_flops;
        self.rescale_folds += w.rescale_folds;
    }

    fn merge(&mut self, o: &AttribStats) {
        self.gather_bytes += o.gather_bytes;
        self.tiles += o.tiles;
        self.softmax_flops += o.softmax_flops;
        self.rescale_folds += o.rescale_folds;
    }
}

/// Which decode gather path materialized a step's KV bytes — the one
/// taxonomy [`Metrics::record_gather`] routes every gather-byte record
/// through, so the three engine branches cannot drift in what they
/// count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GatherKind {
    /// Dense per-lane gather (no sharing, no selection).
    Flat,
    /// Deduplicated cascade gather (shared prefix runs counted once).
    Shared,
    /// Sparse gather over selected pages only.
    Selected,
}

/// Online invariant-audit counters ([`crate::coordinator::AuditPlan`]):
/// sampled consistency checks the engine runs every N steps.
#[derive(Clone, Debug, Default)]
pub struct AuditStats {
    /// Audit passes executed (each pass runs every check once).
    pub runs: usize,
    /// Individual check failures observed across passes.
    pub failures: usize,
    /// Wall-clock spent inside audit passes, microseconds.
    pub audit_us: f64,
}

impl AuditStats {
    fn merge(&mut self, o: &AuditStats) {
        self.runs += o.runs;
        self.failures += o.failures;
        self.audit_us += o.audit_us;
    }
}

/// Partition-balance and cost-model-drift plane
/// ([`crate::obs::balance`] / [`crate::obs::drift`]): the latest
/// projected step's stream-K plan quality and the online drift
/// detector's state.
#[derive(Clone, Debug, Default)]
pub struct BalanceStats {
    /// Drift observations fed to the detector (including warmup).
    pub drift_observations: u64,
    /// Sustained cost-model breaches the detector declared.
    pub drift_breaches: u64,
    /// Current relative-error EWMA of the cost model (gauge).
    pub drift_rel_err: f64,
    /// Load-imbalance factor (makespan over mean busy-slot time) of the
    /// latest step's stream-K plan (gauge; 1.0 = perfectly level).
    pub partition_imbalance: f64,
    /// Wave efficiency (busy slot-time over makespan x slots) of the
    /// latest step's stream-K plan (gauge; 1.0 = no quantization waste).
    pub wave_efficiency: f64,
}

impl BalanceStats {
    fn merge(&mut self, o: &BalanceStats) {
        self.drift_observations += o.drift_observations;
        self.drift_breaches += o.drift_breaches;
        // Point-in-time gauges, not counters — when folding replicas,
        // surface the worst drift / imbalance and the best efficiency
        // actually observed rather than summing meaningless totals.
        self.drift_rel_err = self.drift_rel_err.max(o.drift_rel_err);
        self.partition_imbalance = self.partition_imbalance.max(o.partition_imbalance);
        self.wave_efficiency = self.wave_efficiency.max(o.wave_efficiency);
    }
}

/// Parallel-sampling (fork/prune) counters.
#[derive(Clone, Debug, Default)]
pub struct SamplingStats {
    /// `Engine::fork` calls served.
    pub fork_calls: usize,
    /// Sibling sequences created by forks (refcount-only — zero page
    /// copies at fork time; divergence COWs show up in
    /// [`PrefixCacheStats::cow_copies`]).
    pub forked_siblings: usize,
    /// Sequences cancelled mid-generation (beam pruning).
    pub cancelled: usize,
}

impl SamplingStats {
    fn merge(&mut self, o: &SamplingStats) {
        self.fork_calls += o.fork_calls;
        self.forked_siblings += o.forked_siblings;
        self.cancelled += o.cancelled;
    }
}

/// Every metric [`Metrics::snapshot`] exports, in exposition order —
/// the documented surface the consistency audit (`tests/obs_props.rs`)
/// diffs against both exporter outputs so nothing is silently dropped.
pub const DOCUMENTED_METRICS: &[&str] = &[
    "prefill_calls_total",
    "decode_steps_total",
    "tokens_generated_total",
    "requests_finished_total",
    "decode_tokens_per_s",
    "step_us_count",
    "step_us_sum",
    "step_us_p50",
    "step_us_p95",
    "step_us_p99",
    "step_us_p999",
    "prefill_us_count",
    "prefill_us_sum",
    "prefill_us_p50",
    "prefill_us_p95",
    "prefill_us_p99",
    "prefill_us_p999",
    "prefix_lookups_total",
    "prefix_hits_total",
    "prefix_hit_rate",
    "prefix_tokens_matched_total",
    "prefix_pages_shared_total",
    "prefix_kv_bytes_deduped_total",
    "prefix_evicted_pages_total",
    "prefix_cow_copies_total",
    "sampling_fork_calls_total",
    "sampling_forked_siblings_total",
    "sampling_cancelled_total",
    "spec_verify_passes_total",
    "spec_drafted_total",
    "spec_accepted_total",
    "spec_committed_total",
    "spec_rolled_back_total",
    "spec_acceptance_rate",
    "sparse_selection_steps_total",
    "sparse_lanes_scored_total",
    "sparse_pages_considered_total",
    "sparse_pages_scanned_total",
    "sparse_scan_fraction",
    "sparse_gather_bytes_dense_total",
    "sparse_gather_bytes_sparse_total",
    "sparse_mean_coverage",
    "cascade_gather_steps_total",
    "gather_bytes_flat_total",
    "gather_bytes_shared_total",
    "projected_speedup",
    "projected_occupancy",
    "projected_cascade_us_mean",
    "cascade_kv_bytes_saved_total",
    "gqa_kv_heads",
    "gqa_group_size",
    "gqa_gather_bytes_grouped_total",
    "gqa_gather_bytes_dense_total",
    "attrib_gather_bytes_total",
    "attrib_tiles_total",
    "attrib_softmax_flops_total",
    "attrib_rescale_folds_total",
    "audit_runs_total",
    "audit_failures_total",
    "audit_us_total",
    "drift_observations_total",
    "drift_breaches_total",
    "drift_rel_err",
    "partition_imbalance",
    "wave_efficiency",
];

/// Accumulated engine counters.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub prefill_calls: usize,
    pub decode_steps: usize,
    pub tokens_generated: usize,
    pub requests_finished: usize,
    /// Wall-clock of each decode step, microseconds (bounded histogram).
    pub step_us: LogHistogram,
    /// Wall-clock of each prefill call, microseconds (bounded histogram).
    pub prefill_us: LogHistogram,
    /// Projected GPU attention latency per step under LeanAttention (us).
    pub projected_lean_us: LogHistogram,
    /// Projected GPU attention latency per step under FlashDecoding (us).
    pub projected_fd_us: LogHistogram,
    /// Sum of projected LeanAttention SM occupancy over projected steps.
    pub projected_occupancy_sum: f64,
    /// Sum of per-step FlashDecoding/LeanAttention latency ratios.
    pub projected_speedup_sum: f64,
    /// Steps contributing to the projection sums.
    pub projected_steps: usize,
    /// Projected attention latency per step under cascade (shared-prefix)
    /// stream-K, when the step's batch had a shared prefix (us).
    pub projected_cascade_us: LogHistogram,
    /// Modeled KV bytes the cascade plan avoided streaming, summed over
    /// projected steps (shared prefix counted once per group, not per
    /// sequence).
    pub cascade_kv_bytes_saved: f64,
    /// Decode steps that took the cascade (deduplicated) gather path
    /// because batch lanes physically shared a leading KV page run.
    pub cascade_gather_steps: usize,
    /// K+V bytes a flat gather would have materialized on those steps.
    pub gather_bytes_flat: u64,
    /// K+V bytes the cascade gather actually materialized (each shared
    /// page run once per group instead of once per lane).
    pub gather_bytes_shared: u64,
    /// Prefix-cache counters.
    pub prefix: PrefixCacheStats,
    /// Parallel-sampling counters.
    pub sampling: SamplingStats,
    /// Speculative-decoding counters (draft-and-verify passes).
    pub spec: SpecStats,
    /// Sparse page-selection counters (long-context decode).
    pub sparse: SparseStats,
    /// Grouped-query attention plane gauges (kv heads, group size,
    /// grouped-vs-dense gather bytes).
    pub gqa: GqaStats,
    /// Exact work-attribution totals (gather bytes, tiles, flops, folds).
    pub attrib: AttribStats,
    /// Sampled online invariant-audit counters.
    pub audit: AuditStats,
    /// Partition-balance and cost-model-drift plane gauges.
    pub balance: BalanceStats,
}

impl Metrics {
    pub fn step_summary(&self) -> Option<Summary> {
        Summary::from_histogram(&self.step_us)
    }

    pub fn prefill_summary(&self) -> Option<Summary> {
        Summary::from_histogram(&self.prefill_us)
    }

    /// Record one step's hardware projection (LeanAttention vs
    /// FlashDecoding latency plus LeanAttention occupancy).
    pub fn record_projection(&mut self, lean_us: f64, fd_us: f64, occupancy: f64) {
        self.projected_lean_us.record(lean_us);
        self.projected_fd_us.record(fd_us);
        self.projected_occupancy_sum += occupancy;
        if lean_us > 0.0 {
            self.projected_speedup_sum += fd_us / lean_us;
        }
        self.projected_steps += 1;
    }

    /// Route one decode gather's materialized K+V bytes into every
    /// counter family that accounts gather traffic — the single helper
    /// all three engine gather branches call, unit-tested so each branch
    /// provably lands in the same counters. Grouped-plane (GQA)
    /// accounting covers the dense paths; the selected path reports
    /// through the sparse byte pair instead (its dense baseline is
    /// recorded separately by the selection step), and every path feeds
    /// the exact attribution total.
    pub fn record_gather(&mut self, kind: GatherKind, bytes: u64) {
        match kind {
            GatherKind::Flat | GatherKind::Shared => self.gqa.record_gather(bytes),
            GatherKind::Selected => self.sparse.gather_bytes_sparse += bytes,
        }
        self.attrib.gather_bytes += bytes;
    }

    /// Record one shared-prefix step's cascade projection.
    pub fn record_cascade_projection(&mut self, cascade_us: f64, kv_bytes_saved: f64) {
        self.projected_cascade_us.record(cascade_us);
        self.cascade_kv_bytes_saved += kv_bytes_saved;
    }

    /// Mean projected speedup of LeanAttention over FlashDecoding across
    /// the steps this engine served.
    pub fn projected_speedup(&self) -> Option<f64> {
        if self.projected_steps == 0 {
            return None;
        }
        Some(self.projected_speedup_sum / self.projected_steps as f64)
    }

    /// Mean projected LeanAttention occupancy across projected steps.
    pub fn projected_occupancy(&self) -> f64 {
        if self.projected_steps == 0 {
            return 0.0;
        }
        self.projected_occupancy_sum / self.projected_steps as f64
    }

    /// Tokens per second of decode wall-clock.
    pub fn decode_tps(&self) -> f64 {
        let total_s: f64 = self.step_us.sum() * 1e-6;
        if total_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / total_s
        }
    }

    /// Fold another engine's metrics in (multi-replica router totals).
    pub fn merge(&mut self, o: &Metrics) {
        self.prefill_calls += o.prefill_calls;
        self.decode_steps += o.decode_steps;
        self.tokens_generated += o.tokens_generated;
        self.requests_finished += o.requests_finished;
        self.step_us.merge(&o.step_us);
        self.prefill_us.merge(&o.prefill_us);
        self.projected_lean_us.merge(&o.projected_lean_us);
        self.projected_fd_us.merge(&o.projected_fd_us);
        self.projected_occupancy_sum += o.projected_occupancy_sum;
        self.projected_speedup_sum += o.projected_speedup_sum;
        self.projected_steps += o.projected_steps;
        self.projected_cascade_us.merge(&o.projected_cascade_us);
        self.cascade_kv_bytes_saved += o.cascade_kv_bytes_saved;
        self.cascade_gather_steps += o.cascade_gather_steps;
        self.gather_bytes_flat += o.gather_bytes_flat;
        self.gather_bytes_shared += o.gather_bytes_shared;
        self.prefix.merge(&o.prefix);
        self.sampling.merge(&o.sampling);
        self.spec.merge(&o.spec);
        self.sparse.merge(&o.sparse);
        self.gqa.merge(&o.gqa);
        self.attrib.merge(&o.attrib);
        self.audit.merge(&o.audit);
        self.balance.merge(&o.balance);
    }

    /// Sample every documented metric into the one snapshot both
    /// exporters serialize. Names match [`DOCUMENTED_METRICS`] exactly.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::new();
        s.counter("prefill_calls_total", self.prefill_calls as f64, "Prefill calls served.");
        s.counter("decode_steps_total", self.decode_steps as f64, "Decode steps taken.");
        s.counter(
            "tokens_generated_total",
            self.tokens_generated as f64,
            "Tokens sampled across all sequences.",
        );
        s.counter(
            "requests_finished_total",
            self.requests_finished as f64,
            "Requests run to completion.",
        );
        s.gauge("decode_tokens_per_s", self.decode_tps(), "Decode throughput, tokens/s.");
        s.counter("step_us_count", self.step_us.count() as f64, "Decode steps timed.");
        s.counter("step_us_sum", self.step_us.sum(), "Total decode step wall-clock (us).");
        s.gauge("step_us_p50", self.step_us.quantile(0.5), "p50 decode step latency (us).");
        s.gauge("step_us_p95", self.step_us.quantile(0.95), "p95 decode step latency (us).");
        s.gauge("step_us_p99", self.step_us.quantile(0.99), "p99 decode step latency (us).");
        s.gauge("step_us_p999", self.step_us.quantile(0.999), "p999 decode step latency (us).");
        s.counter("prefill_us_count", self.prefill_us.count() as f64, "Prefill calls timed.");
        s.counter("prefill_us_sum", self.prefill_us.sum(), "Total prefill wall-clock (us).");
        s.gauge("prefill_us_p50", self.prefill_us.quantile(0.5), "p50 prefill latency (us).");
        s.gauge("prefill_us_p95", self.prefill_us.quantile(0.95), "p95 prefill latency (us).");
        s.gauge("prefill_us_p99", self.prefill_us.quantile(0.99), "p99 prefill latency (us).");
        s.gauge("prefill_us_p999", self.prefill_us.quantile(0.999), "p999 prefill latency (us).");
        s.counter("prefix_lookups_total", self.prefix.lookups as f64, "Prefix-index probes.");
        s.counter("prefix_hits_total", self.prefix.hits as f64, "Prompts reusing cached pages.");
        s.gauge("prefix_hit_rate", self.prefix.hit_rate(), "Prefix-cache hit rate per probe.");
        s.counter(
            "prefix_tokens_matched_total",
            self.prefix.tokens_matched as f64,
            "Prompt tokens served from cached prefix pages.",
        );
        s.counter(
            "prefix_pages_shared_total",
            self.prefix.pages_shared as f64,
            "Page references taken on cached prefix pages.",
        );
        s.counter(
            "prefix_kv_bytes_deduped_total",
            self.prefix.kv_bytes_deduped as f64,
            "KV bytes deduplicated by prefix sharing.",
        );
        s.counter(
            "prefix_evicted_pages_total",
            self.prefix.evicted_pages as f64,
            "Prefix-index pages evicted under pressure.",
        );
        s.counter(
            "prefix_cow_copies_total",
            self.prefix.cow_copies as f64,
            "Copy-on-write page clones.",
        );
        s.counter(
            "sampling_fork_calls_total",
            self.sampling.fork_calls as f64,
            "Engine::fork calls served.",
        );
        s.counter(
            "sampling_forked_siblings_total",
            self.sampling.forked_siblings as f64,
            "Sibling sequences created by forks.",
        );
        s.counter(
            "sampling_cancelled_total",
            self.sampling.cancelled as f64,
            "Sequences cancelled mid-generation.",
        );
        s.counter(
            "spec_verify_passes_total",
            self.spec.verify_passes as f64,
            "Speculative verify passes run.",
        );
        s.counter("spec_drafted_total", self.spec.drafted as f64, "Draft tokens proposed.");
        s.counter("spec_accepted_total", self.spec.accepted as f64, "Draft tokens accepted.");
        s.counter(
            "spec_committed_total",
            self.spec.committed as f64,
            "Tokens committed by verify passes.",
        );
        s.counter(
            "spec_rolled_back_total",
            self.spec.rolled_back as f64,
            "Speculative KV rows rolled back.",
        );
        s.gauge(
            "spec_acceptance_rate",
            self.spec.acceptance_rate(),
            "Fraction of drafted tokens accepted.",
        );
        s.counter(
            "sparse_selection_steps_total",
            self.sparse.selection_steps as f64,
            "Decode steps using sparse page selection.",
        );
        s.counter(
            "sparse_lanes_scored_total",
            self.sparse.lanes_scored as f64,
            "Lanes whose pages were scored.",
        );
        s.counter(
            "sparse_pages_considered_total",
            self.sparse.pages_total as f64,
            "Context pages considered by selection.",
        );
        s.counter(
            "sparse_pages_scanned_total",
            self.sparse.pages_scanned as f64,
            "Pages kept by selection (scanned).",
        );
        s.gauge(
            "sparse_scan_fraction",
            self.sparse.scan_fraction(),
            "Fraction of considered pages scanned.",
        );
        s.counter(
            "sparse_gather_bytes_dense_total",
            self.sparse.gather_bytes_dense as f64,
            "KV bytes a dense gather would have moved.",
        );
        s.counter(
            "sparse_gather_bytes_sparse_total",
            self.sparse.gather_bytes_sparse as f64,
            "KV bytes the sparse gather moved.",
        );
        s.gauge(
            "sparse_mean_coverage",
            self.sparse.mean_coverage(),
            "Mean score-mass coverage of selections.",
        );
        s.counter(
            "cascade_gather_steps_total",
            self.cascade_gather_steps as f64,
            "Steps taking the deduplicated cascade gather.",
        );
        s.counter(
            "gather_bytes_flat_total",
            self.gather_bytes_flat as f64,
            "KV bytes a flat gather would have moved.",
        );
        s.counter(
            "gather_bytes_shared_total",
            self.gather_bytes_shared as f64,
            "KV bytes the cascade gather moved.",
        );
        s.gauge(
            "projected_speedup",
            self.projected_speedup().unwrap_or(0.0),
            "Mean projected LeanAttention speedup over FlashDecoding.",
        );
        s.gauge(
            "projected_occupancy",
            self.projected_occupancy(),
            "Mean projected LeanAttention SM occupancy.",
        );
        s.gauge(
            "projected_cascade_us_mean",
            self.projected_cascade_us.mean(),
            "Mean projected cascade attention latency (us).",
        );
        s.counter(
            "cascade_kv_bytes_saved_total",
            self.cascade_kv_bytes_saved,
            "Modeled KV bytes the cascade plan avoided streaming.",
        );
        s.gauge(
            "gqa_kv_heads",
            self.gqa.kv_heads as f64,
            "KV heads per layer (the cache/gather granularity).",
        );
        s.gauge(
            "gqa_group_size",
            self.gqa.group_size as f64,
            "Query heads sharing each KV head (h / h_kv).",
        );
        s.counter(
            "gqa_gather_bytes_grouped_total",
            self.gqa.gather_bytes_grouped as f64,
            "KV bytes decode gathers moved at kv-head granularity.",
        );
        s.counter(
            "gqa_gather_bytes_dense_total",
            self.gqa.gather_bytes_dense as f64,
            "KV bytes a per-query-head plane would have gathered.",
        );
        s.counter(
            "attrib_gather_bytes_total",
            self.attrib.gather_bytes as f64,
            "KV bytes decode gathers moved, attrib-accounted.",
        );
        s.counter(
            "attrib_tiles_total",
            self.attrib.tiles as f64,
            "LeanTiles visited by per-step decode plans.",
        );
        s.counter(
            "attrib_softmax_flops_total",
            self.attrib.softmax_flops as f64,
            "Online-softmax flops of per-step decode plans.",
        );
        s.counter(
            "attrib_rescale_folds_total",
            self.attrib.rescale_folds as f64,
            "Rescale folds of per-step decode plans.",
        );
        s.counter(
            "audit_runs_total",
            self.audit.runs as f64,
            "Sampled invariant-audit passes executed.",
        );
        s.counter(
            "audit_failures_total",
            self.audit.failures as f64,
            "Invariant-audit check failures observed.",
        );
        s.counter(
            "audit_us_total",
            self.audit.audit_us,
            "Wall-clock spent in audit passes (us).",
        );
        s.counter(
            "drift_observations_total",
            self.balance.drift_observations as f64,
            "Cost-model drift observations fed (incl. warmup).",
        );
        s.counter(
            "drift_breaches_total",
            self.balance.drift_breaches as f64,
            "Sustained cost-model drift breaches declared.",
        );
        s.gauge(
            "drift_rel_err",
            self.balance.drift_rel_err,
            "Relative-error EWMA of the online cost model.",
        );
        s.gauge(
            "partition_imbalance",
            self.balance.partition_imbalance,
            "Load-imbalance factor of the latest stream-K plan.",
        );
        s.gauge(
            "wave_efficiency",
            self.balance.wave_efficiency,
            "Wave efficiency of the latest stream-K plan.",
        );
        s
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "prefills={} steps={} tokens={} finished={}\n",
            self.prefill_calls,
            self.decode_steps,
            self.tokens_generated,
            self.requests_finished
        ));
        if let Some(sm) = self.step_summary() {
            s.push_str(&format!(
                "step_us: mean={:.0} p50={:.0} p95={:.0} p99={:.0}\n",
                sm.mean, sm.p50, sm.p95, sm.p99
            ));
        }
        if let Some(sm) = self.prefill_summary() {
            s.push_str(&format!(
                "prefill_us: mean={:.0} p50={:.0} p95={:.0} p99={:.0}\n",
                sm.mean, sm.p50, sm.p95, sm.p99
            ));
        }
        s.push_str(&format!("decode throughput: {:.1} tok/s\n", self.decode_tps()));
        if self.prefix.lookups > 0 {
            s.push_str(&format!(
                "prefix cache: hit rate {:.0}% ({} hits / {} probes), {} tokens from cache, \
                 {} pages shared, {:.1} KiB KV deduplicated, {} pages evicted, {} COW copies\n",
                self.prefix.hit_rate() * 100.0,
                self.prefix.hits,
                self.prefix.lookups,
                self.prefix.tokens_matched,
                self.prefix.pages_shared,
                self.prefix.kv_bytes_deduped as f64 / 1024.0,
                self.prefix.evicted_pages,
                self.prefix.cow_copies,
            ));
        }
        if self.sampling.fork_calls > 0 {
            s.push_str(&format!(
                "parallel sampling: {} forks created {} siblings (zero-copy), {} pruned\n",
                self.sampling.fork_calls,
                self.sampling.forked_siblings,
                self.sampling.cancelled,
            ));
        }
        if self.spec.verify_passes > 0 {
            s.push_str(&format!(
                "speculative decode: {} verify passes committed {} tokens \
                 ({:.2} tokens/pass), {}/{} drafts accepted ({:.0}%), \
                 {} draft KV rows rolled back\n",
                self.spec.verify_passes,
                self.spec.committed,
                self.spec.tokens_per_pass(),
                self.spec.accepted,
                self.spec.drafted,
                self.spec.acceptance_rate() * 100.0,
                self.spec.rolled_back,
            ));
        }
        if self.sparse.selection_steps > 0 {
            let saved = if self.sparse.gather_bytes_dense > 0 {
                100.0
                    * (1.0
                        - self.sparse.gather_bytes_sparse as f64
                            / self.sparse.gather_bytes_dense as f64)
            } else {
                0.0
            };
            s.push_str(&format!(
                "sparse selection: {} steps scanned {}/{} pages ({:.0}%), \
                 {:.1} KiB gathered vs {:.1} KiB dense ({saved:.0}% saved), \
                 mean coverage {:.2}\n",
                self.sparse.selection_steps,
                self.sparse.pages_scanned,
                self.sparse.pages_total,
                self.sparse.scan_fraction() * 100.0,
                self.sparse.gather_bytes_sparse as f64 / 1024.0,
                self.sparse.gather_bytes_dense as f64 / 1024.0,
                self.sparse.mean_coverage(),
            ));
        }
        if self.gqa.group_size > 1 && self.gqa.gather_bytes_grouped > 0 {
            s.push_str(&format!(
                "gqa plane: {} kv heads x{} group size, {:.1} KiB gathered \
                 vs {:.1} KiB per-query-head dense ({:.1}x less KV traffic)\n",
                self.gqa.kv_heads,
                self.gqa.group_size,
                self.gqa.gather_bytes_grouped as f64 / 1024.0,
                self.gqa.gather_bytes_dense as f64 / 1024.0,
                self.gqa.gather_bytes_dense as f64
                    / self.gqa.gather_bytes_grouped as f64,
            ));
        }
        if self.attrib.tiles > 0 {
            s.push_str(&format!(
                "work attribution: {} tiles, {:.1} KiB gathered, {:.2} Mflop softmax, \
                 {} rescale folds\n",
                self.attrib.tiles,
                self.attrib.gather_bytes as f64 / 1024.0,
                self.attrib.softmax_flops as f64 / 1e6,
                self.attrib.rescale_folds,
            ));
        }
        if self.audit.runs > 0 {
            s.push_str(&format!(
                "invariant audits: {} passes, {} failures, {:.0}us total\n",
                self.audit.runs, self.audit.failures, self.audit.audit_us,
            ));
        }
        if self.balance.partition_imbalance > 0.0 {
            s.push_str(&format!(
                "partition balance: imbalance {:.3}, wave efficiency {:.3}\n",
                self.balance.partition_imbalance, self.balance.wave_efficiency,
            ));
        }
        if self.balance.drift_observations > 0 {
            s.push_str(&format!(
                "cost-model drift: {} observations, rel err EWMA {:.3}, {} breaches\n",
                self.balance.drift_observations,
                self.balance.drift_rel_err,
                self.balance.drift_breaches,
            ));
        }
        if let Some(sp) = self.projected_speedup() {
            s.push_str(&format!(
                "projected on A100: LeanAttention {sp:.2}x over FlashDecoding, occupancy {:.0}%\n",
                self.projected_occupancy() * 100.0
            ));
        }
        if self.cascade_gather_steps > 0 {
            let dedup = if self.gather_bytes_flat > 0 {
                100.0 * (1.0 - self.gather_bytes_shared as f64 / self.gather_bytes_flat as f64)
            } else {
                0.0
            };
            s.push_str(&format!(
                "cascade gather: {} shared-prefix steps materialized {:.1} KiB \
                 vs {:.1} KiB flat ({dedup:.0}% deduped)\n",
                self.cascade_gather_steps,
                self.gather_bytes_shared as f64 / 1024.0,
                self.gather_bytes_flat as f64 / 1024.0,
            ));
        }
        if !self.projected_cascade_us.is_empty() {
            s.push_str(&format!(
                "projected cascade: mean {:.1}us attention/step over {} shared-prefix steps, \
                 {:.1} KiB modeled KV traffic saved\n",
                self.projected_cascade_us.mean(),
                self.projected_cascade_us.count(),
                self.cascade_kv_bytes_saved / 1024.0,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert!(m.step_summary().is_none());
        assert!(m.projected_speedup().is_none());
        assert_eq!(m.decode_tps(), 0.0);
        assert!(m.report().contains("steps=0"));
        assert!(!m.report().contains("prefix cache"));
        assert_eq!(m.prefix.hit_rate(), 0.0);
    }

    #[test]
    fn speedup_and_tps() {
        let mut m = Metrics { decode_steps: 2, tokens_generated: 4, ..Default::default() };
        m.step_us.record(1000.0);
        m.step_us.record(1000.0);
        m.record_projection(10.0, 20.0, 0.9);
        m.record_projection(10.0, 15.0, 0.7);
        assert!((m.projected_speedup().unwrap() - 1.75).abs() < 1e-12);
        assert!((m.projected_occupancy() - 0.8).abs() < 1e-12);
        assert!((m.decode_tps() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn prefix_stats_in_report() {
        let m = Metrics {
            prefix: PrefixCacheStats {
                lookups: 4,
                hits: 3,
                tokens_matched: 96,
                pages_shared: 6,
                kv_bytes_deduped: 6 * 2048,
                evicted_pages: 1,
                cow_copies: 0,
            },
            ..Default::default()
        };
        assert!((m.prefix.hit_rate() - 0.75).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("hit rate 75%"), "{rep}");
        assert!(rep.contains("6 pages shared"), "{rep}");
    }

    #[test]
    fn hit_rate_counts_every_probe_not_every_request() {
        // Two admitted requests hit, but the index was probed six times
        // (gate peeks of queued/rejected requests included): the rate is
        // per probe, so skew from uncounted gate probes is gone.
        let m = Metrics {
            prefix: PrefixCacheStats { lookups: 6, hits: 2, ..Default::default() },
            ..Default::default()
        };
        assert!((m.prefix.hit_rate() - 2.0 / 6.0).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("2 hits / 6 probes"), "{rep}");
    }

    #[test]
    fn cascade_gather_dedup_in_report() {
        let m = Metrics {
            cascade_gather_steps: 3,
            gather_bytes_flat: 4096,
            gather_bytes_shared: 1024,
            ..Default::default()
        };
        let rep = m.report();
        assert!(rep.contains("cascade gather: 3 shared-prefix steps"), "{rep}");
        assert!(rep.contains("75% deduped"), "{rep}");
        // Absent when no shared step ran.
        assert!(!Metrics::default().report().contains("cascade gather"));
    }

    #[test]
    fn sampling_stats_in_report_only_after_forks() {
        assert!(!Metrics::default().report().contains("parallel sampling"));
        let m = Metrics {
            sampling: SamplingStats { fork_calls: 2, forked_siblings: 6, cancelled: 3 },
            ..Default::default()
        };
        let rep = m.report();
        assert!(rep.contains("2 forks created 6 siblings"), "{rep}");
        assert!(rep.contains("3 pruned"), "{rep}");
    }

    #[test]
    fn spec_stats_in_report_only_after_verify_passes() {
        assert!(!Metrics::default().report().contains("speculative decode"));
        let m = Metrics {
            spec: SpecStats {
                verify_passes: 5,
                drafted: 20,
                accepted: 15,
                committed: 20,
                rolled_back: 5,
            },
            ..Default::default()
        };
        let rep = m.report();
        assert!(rep.contains("5 verify passes committed 20 tokens"), "{rep}");
        assert!(rep.contains("4.00 tokens/pass"), "{rep}");
        assert!(rep.contains("15/20 drafts accepted (75%)"), "{rep}");
        assert!(rep.contains("5 draft KV rows rolled back"), "{rep}");
    }

    #[test]
    fn sparse_stats_in_report_only_after_selection_steps() {
        assert!(!Metrics::default().report().contains("sparse selection"));
        let m = Metrics {
            sparse: SparseStats {
                selection_steps: 4,
                lanes_scored: 4,
                pages_total: 40,
                pages_scanned: 10,
                gather_bytes_dense: 8192,
                gather_bytes_sparse: 2048,
                coverage_sum: 3.8,
                coverage_samples: 4,
            },
            ..Default::default()
        };
        assert!((m.sparse.scan_fraction() - 0.25).abs() < 1e-12);
        assert!((m.sparse.mean_coverage() - 0.95).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("4 steps scanned 10/40 pages (25%)"), "{rep}");
        assert!(rep.contains("75% saved"), "{rep}");
        assert!(rep.contains("mean coverage 0.95"), "{rep}");
        // Degenerate defaults are safe.
        assert_eq!(SparseStats::default().scan_fraction(), 1.0);
        assert_eq!(SparseStats::default().mean_coverage(), 1.0);
    }

    #[test]
    fn step_percentiles_surface_p95() {
        let mut m = Metrics::default();
        for x in 1..=100 {
            m.step_us.record(x as f64);
        }
        let rep = m.report();
        assert!(rep.contains("p95="), "{rep}");
        let sm = m.step_summary().unwrap();
        assert!(sm.p50 <= sm.p95 && sm.p95 <= sm.p99);
    }

    #[test]
    fn merge_accumulates_across_replicas() {
        let mut a = Metrics { decode_steps: 2, tokens_generated: 8, ..Default::default() };
        a.step_us.record(100.0);
        a.record_projection(10.0, 20.0, 0.8);
        let mut b = Metrics { decode_steps: 3, tokens_generated: 5, ..Default::default() };
        b.step_us.record(300.0);
        b.record_projection(10.0, 10.0, 0.6);
        b.prefix.lookups = 4;
        b.prefix.hits = 2;
        a.merge(&b);
        assert_eq!(a.decode_steps, 5);
        assert_eq!(a.tokens_generated, 13);
        assert_eq!(a.step_us.count(), 2);
        assert!((a.projected_speedup().unwrap() - 1.5).abs() < 1e-12);
        assert!((a.projected_occupancy() - 0.7).abs() < 1e-12);
        assert_eq!(a.prefix.lookups, 4);
    }

    #[test]
    fn gqa_stats_scale_the_dense_baseline_by_group_size() {
        let mut m = Metrics::default();
        m.gqa.kv_heads = 8;
        m.gqa.group_size = 4;
        m.gqa.record_gather(1024);
        m.gqa.record_gather(1024);
        assert_eq!(m.gqa.gather_bytes_grouped, 2048);
        assert_eq!(m.gqa.gather_bytes_dense, 8192);
        let rep = m.report();
        assert!(rep.contains("gqa plane: 8 kv heads x4 group size"), "{rep}");
        assert!(rep.contains("4.0x less KV traffic"), "{rep}");
        // Ungrouped engines stay silent.
        let mut dense = Metrics::default();
        dense.gqa.kv_heads = 8;
        dense.gqa.group_size = 1;
        dense.gqa.record_gather(1024);
        assert_eq!(dense.gqa.gather_bytes_dense, 1024);
        assert!(!dense.report().contains("gqa plane"));
    }

    #[test]
    fn gqa_merge_is_the_union_of_replica_snapshots() {
        // Two replicas of one deployment: one configured and serving,
        // one fresh (gauges still zero). The merged snapshot must be
        // the union — gauges keep the configured side, byte counters
        // sum — for every gqa_* metric, with no replica double-counted.
        let mut a = Metrics::default();
        a.gqa.kv_heads = 8;
        a.gqa.group_size = 4;
        a.gqa.record_gather(1000);
        a.gqa.record_gather(24);
        let mut b = Metrics::default();
        b.gqa.record_gather(512); // unconfigured: dense == grouped
        let (snap_a, snap_b) = (a.snapshot(), b.snapshot());
        a.merge(&b);
        let merged = a.snapshot();
        for name in ["gqa_kv_heads", "gqa_group_size"] {
            let (va, vb) = (snap_a.get(name).unwrap().value, snap_b.get(name).unwrap().value);
            assert_eq!(merged.get(name).unwrap().value, va.max(vb), "{name}");
        }
        for name in ["gqa_gather_bytes_grouped_total", "gqa_gather_bytes_dense_total"] {
            let (va, vb) = (snap_a.get(name).unwrap().value, snap_b.get(name).unwrap().value);
            assert_eq!(merged.get(name).unwrap().value, va + vb, "{name}");
        }
        assert_eq!(a.gqa.gather_bytes_grouped, 1536);
        assert_eq!(a.gqa.gather_bytes_dense, 4 * 1024 + 512);
    }

    #[test]
    fn attrib_totals_merge_and_export() {
        let w = WorkAccounting {
            tiles: 6,
            gathered_kv_bytes: 9999, // ignored by record_plan
            softmax_flops: 4096,
            rescale_folds: 12,
        };
        let mut a = Metrics::default();
        a.attrib.record_plan(&w);
        a.attrib.gather_bytes += 2048;
        let mut b = Metrics::default();
        b.attrib.record_plan(&w);
        b.attrib.gather_bytes += 1024;
        a.merge(&b);
        assert_eq!(a.attrib.tiles, 12);
        assert_eq!(a.attrib.softmax_flops, 8192);
        assert_eq!(a.attrib.rescale_folds, 24);
        assert_eq!(a.attrib.gather_bytes, 3072, "plan bytes must not leak in");
        let snap = a.snapshot();
        assert_eq!(snap.get("attrib_gather_bytes_total").unwrap().value, 3072.0);
        assert_eq!(snap.get("attrib_tiles_total").unwrap().value, 12.0);
        assert_eq!(snap.get("attrib_softmax_flops_total").unwrap().value, 8192.0);
        assert_eq!(snap.get("attrib_rescale_folds_total").unwrap().value, 24.0);
        assert!(a.report().contains("work attribution: 12 tiles"), "{}", a.report());
    }

    #[test]
    fn record_gather_routes_every_branch_into_the_same_counters() {
        // Flat and shared branches: grouped-plane bytes + attribution.
        let mut m = Metrics::default();
        m.gqa.kv_heads = 4;
        m.gqa.group_size = 2;
        m.record_gather(GatherKind::Flat, 1000);
        m.record_gather(GatherKind::Shared, 500);
        assert_eq!(m.gqa.gather_bytes_grouped, 1500);
        assert_eq!(m.gqa.gather_bytes_dense, 3000);
        assert_eq!(m.attrib.gather_bytes, 1500);
        assert_eq!(m.sparse.gather_bytes_sparse, 0, "dense paths skip sparse");

        // Selected branch: sparse bytes + attribution, never the
        // grouped-plane pair (its dense baseline is step-recorded).
        m.record_gather(GatherKind::Selected, 300);
        assert_eq!(m.sparse.gather_bytes_sparse, 300);
        assert_eq!(m.attrib.gather_bytes, 1800);
        assert_eq!(m.gqa.gather_bytes_grouped, 1500, "selected skips gqa");

        // The snapshot sees the exact same routing.
        let snap = m.snapshot();
        assert_eq!(snap.get("attrib_gather_bytes_total").unwrap().value, 1800.0);
        assert_eq!(snap.get("gqa_gather_bytes_grouped_total").unwrap().value, 1500.0);
        assert_eq!(snap.get("sparse_gather_bytes_sparse_total").unwrap().value, 300.0);
    }

    #[test]
    fn audit_counters_merge_and_export() {
        let mut a = Metrics::default();
        a.audit.runs = 3;
        a.audit.failures = 1;
        a.audit.audit_us = 120.0;
        let mut b = Metrics::default();
        b.audit.runs = 2;
        b.audit.audit_us = 80.0;
        a.merge(&b);
        assert_eq!(a.audit.runs, 5);
        assert_eq!(a.audit.failures, 1);
        assert_eq!(a.audit.audit_us, 200.0);
        let snap = a.snapshot();
        assert_eq!(snap.get("audit_runs_total").unwrap().value, 5.0);
        assert_eq!(snap.get("audit_failures_total").unwrap().value, 1.0);
        assert_eq!(snap.get("audit_us_total").unwrap().value, 200.0);
        assert!(a.report().contains("invariant audits: 5 passes"), "{}", a.report());
        assert!(!Metrics::default().report().contains("invariant audits"));
    }

    #[test]
    fn balance_counters_sum_and_gauges_keep_the_worst_side() {
        let mut a = Metrics::default();
        a.balance.drift_observations = 40;
        a.balance.drift_breaches = 1;
        a.balance.drift_rel_err = 0.12;
        a.balance.partition_imbalance = 1.4;
        a.balance.wave_efficiency = 0.7;
        let mut b = Metrics::default();
        b.balance.drift_observations = 10;
        b.balance.drift_rel_err = 0.03;
        b.balance.partition_imbalance = 1.1;
        b.balance.wave_efficiency = 0.95;
        a.merge(&b);
        assert_eq!(a.balance.drift_observations, 50);
        assert_eq!(a.balance.drift_breaches, 1);
        assert_eq!(a.balance.drift_rel_err, 0.12);
        assert_eq!(a.balance.partition_imbalance, 1.4);
        assert_eq!(a.balance.wave_efficiency, 0.95);
        let snap = a.snapshot();
        assert_eq!(snap.get("drift_observations_total").unwrap().value, 50.0);
        assert_eq!(snap.get("drift_breaches_total").unwrap().value, 1.0);
        assert_eq!(snap.get("drift_rel_err").unwrap().value, 0.12);
        assert_eq!(snap.get("partition_imbalance").unwrap().value, 1.4);
        assert_eq!(snap.get("wave_efficiency").unwrap().value, 0.95);
        let rep = a.report();
        assert!(rep.contains("partition balance: imbalance 1.400"), "{rep}");
        assert!(rep.contains("cost-model drift: 50 observations"), "{rep}");
        assert!(!Metrics::default().report().contains("partition balance"));
        assert!(!Metrics::default().report().contains("cost-model drift"));
    }

    #[test]
    fn snapshot_exports_exactly_the_documented_metrics() {
        let mut m = Metrics { decode_steps: 7, tokens_generated: 21, ..Default::default() };
        m.step_us.record(250.0);
        let snap = m.snapshot();
        assert_eq!(snap.names(), DOCUMENTED_METRICS.to_vec());
        assert_eq!(snap.get("decode_steps_total").unwrap().value, 7.0);
        assert_eq!(snap.get("step_us_count").unwrap().value, 1.0);
        let text = snap.to_prometheus();
        for name in DOCUMENTED_METRICS {
            assert!(text.contains(&format!("leanattn_{name} ")), "{name} missing");
        }
    }
}
