//! Engine metrics: step counters, token throughput, latency percentiles,
//! prefix-cache accounting, and the per-step LeanAttention-vs-FlashDecoding
//! hardware projection the engine records (linking the serving loop back
//! to the paper's contribution).

use crate::spec::SpecStats;
use crate::util::stats::Summary;

/// Prefix-cache (radix index) counters.
#[derive(Clone, Debug, Default)]
pub struct PrefixCacheStats {
    /// Index probes — admission-gate peeks (including requests that were
    /// rejected or left queued), eviction-pass peeks, and the post-prefill
    /// registration lookups. A single request can account for several
    /// probes, so this counts actual index traffic, not admitted prompts.
    pub lookups: usize,
    /// Admitted prompts that matched at least one full page.
    pub hits: usize,
    /// Prompt tokens served from cached prefix pages.
    pub tokens_matched: usize,
    /// Page references taken on cached prefix pages by admitted sequences.
    pub pages_shared: usize,
    /// K+V bytes the shared pages would otherwise have duplicated.
    pub kv_bytes_deduped: u64,
    /// Index pages evicted under cache pressure.
    pub evicted_pages: usize,
    /// Copy-on-write page clones performed by the cache.
    pub cow_copies: usize,
}

impl PrefixCacheStats {
    /// Fraction of index probes that led to an admitted prompt reusing at
    /// least one cached prefix page.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

/// Sparse page-selection counters (long-context decode).
#[derive(Clone, Debug, Default)]
pub struct SparseStats {
    /// Decode steps that gathered through the selected-page sparse path.
    pub selection_steps: usize,
    /// Lanes whose context pages were actually scored (dense-threshold
    /// bypasses excluded).
    pub lanes_scored: usize,
    /// Context pages considered across scored lanes.
    pub pages_total: usize,
    /// Pages the selections kept — what the step actually scanned.
    pub pages_scanned: usize,
    /// K+V bytes a dense gather would have materialized on sparse steps
    /// (per lane, full context).
    pub gather_bytes_dense: u64,
    /// K+V bytes of the selected pages, counted per lane so the ratio
    /// against `gather_bytes_dense` isolates pure selection — cascade
    /// dedup of shared sink runs (which the dense path enjoys too) is
    /// reported by the cascade gather counters, not here.
    pub gather_bytes_sparse: u64,
    /// Sum of per-lane score-mass coverage: the softmax-weighted share
    /// of page upper-bound scores the selection retained (a proxy for
    /// attention-mass coverage).
    pub coverage_sum: f64,
    /// Lanes contributing to `coverage_sum`.
    pub coverage_samples: usize,
}

impl SparseStats {
    /// Fold one scored lane's selection into the counters — the single
    /// accounting both the engine and the bench harness use.
    pub fn record_scored_lane(&mut self, scores: &[f32], selected: &[usize]) {
        self.lanes_scored += 1;
        self.pages_total += scores.len();
        self.pages_scanned += selected.len();
        self.coverage_sum += crate::sparse::score_coverage(scores, selected);
        self.coverage_samples += 1;
    }

    /// Fraction of considered pages the selections kept.
    pub fn scan_fraction(&self) -> f64 {
        if self.pages_total == 0 {
            1.0
        } else {
            self.pages_scanned as f64 / self.pages_total as f64
        }
    }

    /// Mean score-mass coverage across scored lanes.
    pub fn mean_coverage(&self) -> f64 {
        if self.coverage_samples == 0 {
            1.0
        } else {
            self.coverage_sum / self.coverage_samples as f64
        }
    }
}

/// Parallel-sampling (fork/prune) counters.
#[derive(Clone, Debug, Default)]
pub struct SamplingStats {
    /// `Engine::fork` calls served.
    pub fork_calls: usize,
    /// Sibling sequences created by forks (refcount-only — zero page
    /// copies at fork time; divergence COWs show up in
    /// [`PrefixCacheStats::cow_copies`]).
    pub forked_siblings: usize,
    /// Sequences cancelled mid-generation (beam pruning).
    pub cancelled: usize,
}

/// Accumulated engine counters.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub prefill_calls: usize,
    pub decode_steps: usize,
    pub tokens_generated: usize,
    pub requests_finished: usize,
    /// Wall-clock of each decode step, microseconds.
    pub step_us: Vec<f64>,
    /// Wall-clock of each prefill call, microseconds.
    pub prefill_us: Vec<f64>,
    /// Projected GPU attention latency per step under LeanAttention (us).
    pub projected_lean_us: Vec<f64>,
    /// Projected GPU attention latency per step under FlashDecoding (us).
    pub projected_fd_us: Vec<f64>,
    /// Projected LeanAttention SM occupancy per step.
    pub projected_occupancy: Vec<f64>,
    /// Projected attention latency per step under cascade (shared-prefix)
    /// stream-K, when the step's batch had a shared prefix (us).
    pub projected_cascade_us: Vec<f64>,
    /// Modeled KV bytes the cascade plan avoided streaming, summed over
    /// projected steps (shared prefix counted once per group, not per
    /// sequence).
    pub cascade_kv_bytes_saved: f64,
    /// Decode steps that took the cascade (deduplicated) gather path
    /// because batch lanes physically shared a leading KV page run.
    pub cascade_gather_steps: usize,
    /// K+V bytes a flat gather would have materialized on those steps.
    pub gather_bytes_flat: u64,
    /// K+V bytes the cascade gather actually materialized (each shared
    /// page run once per group instead of once per lane).
    pub gather_bytes_shared: u64,
    /// Prefix-cache counters.
    pub prefix: PrefixCacheStats,
    /// Parallel-sampling counters.
    pub sampling: SamplingStats,
    /// Speculative-decoding counters (draft-and-verify passes).
    pub spec: SpecStats,
    /// Sparse page-selection counters (long-context decode).
    pub sparse: SparseStats,
}

impl Metrics {
    pub fn step_summary(&self) -> Option<Summary> {
        (!self.step_us.is_empty()).then(|| Summary::of(&self.step_us))
    }

    pub fn prefill_summary(&self) -> Option<Summary> {
        (!self.prefill_us.is_empty()).then(|| Summary::of(&self.prefill_us))
    }

    /// Mean projected speedup of LeanAttention over FlashDecoding across
    /// the steps this engine served.
    pub fn projected_speedup(&self) -> Option<f64> {
        if self.projected_fd_us.is_empty() {
            return None;
        }
        let ratios: Vec<f64> = self
            .projected_fd_us
            .iter()
            .zip(&self.projected_lean_us)
            .map(|(fd, la)| fd / la)
            .collect();
        Some(ratios.iter().sum::<f64>() / ratios.len() as f64)
    }

    /// Tokens per second of decode wall-clock.
    pub fn decode_tps(&self) -> f64 {
        let total_s: f64 = self.step_us.iter().sum::<f64>() * 1e-6;
        if total_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / total_s
        }
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "prefills={} steps={} tokens={} finished={}\n",
            self.prefill_calls,
            self.decode_steps,
            self.tokens_generated,
            self.requests_finished
        ));
        if let Some(sm) = self.step_summary() {
            s.push_str(&format!(
                "step_us: mean={:.0} p50={:.0} p95={:.0} p99={:.0}\n",
                sm.mean, sm.p50, sm.p95, sm.p99
            ));
        }
        if let Some(sm) = self.prefill_summary() {
            s.push_str(&format!(
                "prefill_us: mean={:.0} p50={:.0} p95={:.0} p99={:.0}\n",
                sm.mean, sm.p50, sm.p95, sm.p99
            ));
        }
        s.push_str(&format!("decode throughput: {:.1} tok/s\n", self.decode_tps()));
        if self.prefix.lookups > 0 {
            s.push_str(&format!(
                "prefix cache: hit rate {:.0}% ({} hits / {} probes), {} tokens from cache, \
                 {} pages shared, {:.1} KiB KV deduplicated, {} pages evicted, {} COW copies\n",
                self.prefix.hit_rate() * 100.0,
                self.prefix.hits,
                self.prefix.lookups,
                self.prefix.tokens_matched,
                self.prefix.pages_shared,
                self.prefix.kv_bytes_deduped as f64 / 1024.0,
                self.prefix.evicted_pages,
                self.prefix.cow_copies,
            ));
        }
        if self.sampling.fork_calls > 0 {
            s.push_str(&format!(
                "parallel sampling: {} forks created {} siblings (zero-copy), {} pruned\n",
                self.sampling.fork_calls,
                self.sampling.forked_siblings,
                self.sampling.cancelled,
            ));
        }
        if self.spec.verify_passes > 0 {
            s.push_str(&format!(
                "speculative decode: {} verify passes committed {} tokens \
                 ({:.2} tokens/pass), {}/{} drafts accepted ({:.0}%), \
                 {} draft KV rows rolled back\n",
                self.spec.verify_passes,
                self.spec.committed,
                self.spec.tokens_per_pass(),
                self.spec.accepted,
                self.spec.drafted,
                self.spec.acceptance_rate() * 100.0,
                self.spec.rolled_back,
            ));
        }
        if self.sparse.selection_steps > 0 {
            let saved = if self.sparse.gather_bytes_dense > 0 {
                100.0
                    * (1.0
                        - self.sparse.gather_bytes_sparse as f64
                            / self.sparse.gather_bytes_dense as f64)
            } else {
                0.0
            };
            s.push_str(&format!(
                "sparse selection: {} steps scanned {}/{} pages ({:.0}%), \
                 {:.1} KiB gathered vs {:.1} KiB dense ({saved:.0}% saved), \
                 mean coverage {:.2}\n",
                self.sparse.selection_steps,
                self.sparse.pages_scanned,
                self.sparse.pages_total,
                self.sparse.scan_fraction() * 100.0,
                self.sparse.gather_bytes_sparse as f64 / 1024.0,
                self.sparse.gather_bytes_dense as f64 / 1024.0,
                self.sparse.mean_coverage(),
            ));
        }
        if let Some(sp) = self.projected_speedup() {
            let occ = self.projected_occupancy.iter().sum::<f64>()
                / self.projected_occupancy.len().max(1) as f64;
            s.push_str(&format!(
                "projected on A100: LeanAttention {sp:.2}x over FlashDecoding, occupancy {:.0}%\n",
                occ * 100.0
            ));
        }
        if self.cascade_gather_steps > 0 {
            let dedup = if self.gather_bytes_flat > 0 {
                100.0 * (1.0 - self.gather_bytes_shared as f64 / self.gather_bytes_flat as f64)
            } else {
                0.0
            };
            s.push_str(&format!(
                "cascade gather: {} shared-prefix steps materialized {:.1} KiB \
                 vs {:.1} KiB flat ({dedup:.0}% deduped)\n",
                self.cascade_gather_steps,
                self.gather_bytes_shared as f64 / 1024.0,
                self.gather_bytes_flat as f64 / 1024.0,
            ));
        }
        if !self.projected_cascade_us.is_empty() {
            let c: f64 = self.projected_cascade_us.iter().sum::<f64>()
                / self.projected_cascade_us.len() as f64;
            s.push_str(&format!(
                "projected cascade: mean {:.1}us attention/step over {} shared-prefix steps, \
                 {:.1} KiB modeled KV traffic saved\n",
                c,
                self.projected_cascade_us.len(),
                self.cascade_kv_bytes_saved / 1024.0,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert!(m.step_summary().is_none());
        assert!(m.projected_speedup().is_none());
        assert_eq!(m.decode_tps(), 0.0);
        assert!(m.report().contains("steps=0"));
        assert!(!m.report().contains("prefix cache"));
        assert_eq!(m.prefix.hit_rate(), 0.0);
    }

    #[test]
    fn speedup_and_tps() {
        let m = Metrics {
            decode_steps: 2,
            tokens_generated: 4,
            step_us: vec![1000.0, 1000.0],
            projected_lean_us: vec![10.0, 10.0],
            projected_fd_us: vec![20.0, 15.0],
            ..Default::default()
        };
        assert!((m.projected_speedup().unwrap() - 1.75).abs() < 1e-12);
        assert!((m.decode_tps() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn prefix_stats_in_report() {
        let m = Metrics {
            prefix: PrefixCacheStats {
                lookups: 4,
                hits: 3,
                tokens_matched: 96,
                pages_shared: 6,
                kv_bytes_deduped: 6 * 2048,
                evicted_pages: 1,
                cow_copies: 0,
            },
            ..Default::default()
        };
        assert!((m.prefix.hit_rate() - 0.75).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("hit rate 75%"), "{rep}");
        assert!(rep.contains("6 pages shared"), "{rep}");
    }

    #[test]
    fn hit_rate_counts_every_probe_not_every_request() {
        // Two admitted requests hit, but the index was probed six times
        // (gate peeks of queued/rejected requests included): the rate is
        // per probe, so skew from uncounted gate probes is gone.
        let m = Metrics {
            prefix: PrefixCacheStats { lookups: 6, hits: 2, ..Default::default() },
            ..Default::default()
        };
        assert!((m.prefix.hit_rate() - 2.0 / 6.0).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("2 hits / 6 probes"), "{rep}");
    }

    #[test]
    fn cascade_gather_dedup_in_report() {
        let m = Metrics {
            cascade_gather_steps: 3,
            gather_bytes_flat: 4096,
            gather_bytes_shared: 1024,
            ..Default::default()
        };
        let rep = m.report();
        assert!(rep.contains("cascade gather: 3 shared-prefix steps"), "{rep}");
        assert!(rep.contains("75% deduped"), "{rep}");
        // Absent when no shared step ran.
        assert!(!Metrics::default().report().contains("cascade gather"));
    }

    #[test]
    fn sampling_stats_in_report_only_after_forks() {
        assert!(!Metrics::default().report().contains("parallel sampling"));
        let m = Metrics {
            sampling: SamplingStats { fork_calls: 2, forked_siblings: 6, cancelled: 3 },
            ..Default::default()
        };
        let rep = m.report();
        assert!(rep.contains("2 forks created 6 siblings"), "{rep}");
        assert!(rep.contains("3 pruned"), "{rep}");
    }

    #[test]
    fn spec_stats_in_report_only_after_verify_passes() {
        assert!(!Metrics::default().report().contains("speculative decode"));
        let m = Metrics {
            spec: SpecStats {
                verify_passes: 5,
                drafted: 20,
                accepted: 15,
                committed: 20,
                rolled_back: 5,
            },
            ..Default::default()
        };
        let rep = m.report();
        assert!(rep.contains("5 verify passes committed 20 tokens"), "{rep}");
        assert!(rep.contains("4.00 tokens/pass"), "{rep}");
        assert!(rep.contains("15/20 drafts accepted (75%)"), "{rep}");
        assert!(rep.contains("5 draft KV rows rolled back"), "{rep}");
    }

    #[test]
    fn sparse_stats_in_report_only_after_selection_steps() {
        assert!(!Metrics::default().report().contains("sparse selection"));
        let m = Metrics {
            sparse: SparseStats {
                selection_steps: 4,
                lanes_scored: 4,
                pages_total: 40,
                pages_scanned: 10,
                gather_bytes_dense: 8192,
                gather_bytes_sparse: 2048,
                coverage_sum: 3.8,
                coverage_samples: 4,
            },
            ..Default::default()
        };
        assert!((m.sparse.scan_fraction() - 0.25).abs() < 1e-12);
        assert!((m.sparse.mean_coverage() - 0.95).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("4 steps scanned 10/40 pages (25%)"), "{rep}");
        assert!(rep.contains("75% saved"), "{rep}");
        assert!(rep.contains("mean coverage 0.95"), "{rep}");
        // Degenerate defaults are safe.
        assert_eq!(SparseStats::default().scan_fraction(), 1.0);
        assert_eq!(SparseStats::default().mean_coverage(), 1.0);
    }

    #[test]
    fn step_percentiles_surface_p95() {
        let m = Metrics {
            step_us: (1..=100).map(|x| x as f64).collect(),
            ..Default::default()
        };
        let rep = m.report();
        assert!(rep.contains("p95="), "{rep}");
        let sm = m.step_summary().unwrap();
        assert!(sm.p50 <= sm.p95 && sm.p95 <= sm.p99);
    }
}
