//! The decode-serving engine: continuous batching over the PJRT model
//! artifacts with a paged KV cache, greedy sampling, and a per-step
//! LeanAttention hardware projection.
//!
//! One `step()` is one Orca-style iteration: admit waiting requests into
//! free slots (batch prefill), then run one decode step for every active
//! sequence. Python never runs here — both phases execute AOT-compiled
//! HLO through the PJRT CPU client.

use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::partition::plan::{DecodeProblem, Strategy};
use crate::runtime::{Manifest, ModelRuntime, Runtime};
use crate::sim::{simulate, GpuArch};

use super::batcher::ContinuousBatcher;
use super::kv_cache::PagedKvCache;
use super::metrics::Metrics;
use super::request::{FinishReason, FinishedRequest, Request, RequestId};

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Model name in the artifact manifest (`tiny`, `small`, ...).
    pub model: String,
    /// KV-cache pages to allocate.
    pub cache_pages: usize,
    /// Tokens per KV page.
    pub page_tokens: usize,
    /// Record per-step LeanAttention-vs-FlashDecoding GPU projections.
    pub project_hardware: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "tiny".into(),
            cache_pages: 256,
            page_tokens: 16,
            project_hardware: true,
        }
    }
}

struct ActiveSeq {
    prompt_len: usize,
    max_new: usize,
    last_token: i32,
    generated: Vec<i32>,
    arrival: Instant,
    prefill_started: Instant,
    first_token_at: Instant,
    /// KV pages reserved for this request's full budget at admission.
    reserved_pages: usize,
}

/// A single-replica serving engine.
pub struct Engine {
    pub config: EngineConfig,
    model: ModelRuntime,
    cache: PagedKvCache,
    batcher: ContinuousBatcher,
    active: HashMap<RequestId, ActiveSeq>,
    pub metrics: Metrics,
    arch: GpuArch,
    next_id: RequestId,
    /// Sum of KV pages reserved by active requests (admission reserves
    /// the whole prompt+generation budget so decode appends cannot hit a
    /// full cache mid-flight).
    reserved_pages: usize,
    // reusable gather buffers (hot path: no per-step allocation)
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
}

impl Engine {
    /// Load artifacts and bring up the engine.
    pub fn new(runtime: &Rc<Runtime>, manifest: &Manifest, config: EngineConfig) -> Result<Engine> {
        let model = ModelRuntime::load(runtime, manifest, &config.model)
            .with_context(|| format!("load model {:?}", config.model))?;
        let art = &model.art;
        let cache = PagedKvCache::new(
            art.n_layers,
            art.n_heads,
            art.head_dim,
            config.page_tokens,
            config.cache_pages,
        );
        let batcher = ContinuousBatcher::new(art.batch);
        let cache_elems = model.cache_elems();
        Ok(Engine {
            config,
            model,
            cache,
            batcher,
            active: HashMap::new(),
            metrics: Metrics::default(),
            arch: GpuArch::a100(),
            next_id: 1,
            reserved_pages: 0,
            k_buf: vec![0.0; cache_elems],
            v_buf: vec![0.0; cache_elems],
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model.art.name
    }

    pub fn batch_size(&self) -> usize {
        self.model.art.batch
    }

    pub fn ctx_bucket(&self) -> usize {
        self.model.art.ctx_bucket
    }

    pub fn prefill_bucket(&self) -> usize {
        self.model.art.prefill_bucket
    }

    pub fn waiting(&self) -> usize {
        self.batcher.waiting_len()
    }

    pub fn active(&self) -> usize {
        self.batcher.active_len()
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.is_idle()
    }

    /// Submit a request; returns its id. The prompt must fit the prefill
    /// bucket and the vocab.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<RequestId> {
        ensure!(
            !prompt.is_empty() && prompt.len() <= self.model.art.prefill_bucket,
            "prompt length {} outside [1, {}]",
            prompt.len(),
            self.model.art.prefill_bucket
        );
        ensure!(
            prompt.iter().all(|&t| t >= 0 && (t as usize) < self.model.art.vocab),
            "token outside vocab"
        );
        // A request whose full budget can never fit would deadlock the
        // FCFS queue — reject it up front.
        let budget = (prompt.len() + max_new_tokens).min(self.model.art.ctx_bucket);
        ensure!(
            self.cache.pages_for(budget) <= self.cache.total_pages(),
            "request budget of {budget} tokens exceeds total KV capacity"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.enqueue(Request::new(id, prompt, max_new_tokens));
        Ok(id)
    }

    /// One engine iteration: admissions (+ batched prefill) and one decode
    /// step. Returns requests that finished during this iteration.
    pub fn step(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut finished = Vec::new();
        self.admit_and_prefill()?;
        self.decode_once(&mut finished)?;
        Ok(finished)
    }

    /// Drive until every submitted request completes.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    fn admit_and_prefill(&mut self) -> Result<()> {
        let cache = &self.cache;
        // Admit up to the free slots, gated by KV page availability for
        // the prompt plus the *whole* generation budget — reserving as we
        // go, so same-wave admissions and later decode appends can never
        // run the cache dry mid-flight. The budget caps at the ctx bucket
        // (generation stops there with ContextFull regardless).
        let ctx_cap = self.model.art.ctx_bucket;
        let budget = |r: &Request| (r.prompt.len() + r.max_new_tokens).min(ctx_cap);
        let mut reserved = self.reserved_pages;
        let total = cache.total_pages();
        let admitted = self.batcher.admit(|r| {
            let need = cache.pages_for(budget(r));
            if reserved + need <= total {
                reserved += need;
                true
            } else {
                false
            }
        });
        self.reserved_pages = reserved;
        if admitted.is_empty() {
            return Ok(());
        }

        let b = self.model.art.batch;
        let p = self.model.art.prefill_bucket;
        let mut tokens = vec![0i32; b * p];
        let mut lengths = vec![1i32; b]; // dummy lanes prefill 1 token
        for (slot, r) in &admitted {
            tokens[slot * p..slot * p + r.prompt.len()].copy_from_slice(&r.prompt);
            lengths[*slot] = r.prompt.len() as i32;
        }

        let t0 = Instant::now();
        let out = self.model.prefill(&tokens, &lengths)?;
        self.metrics.prefill_calls += 1;
        self.metrics
            .prefill_us
            .push(t0.elapsed().as_secs_f64() * 1e6);

        let (l, h, dh) = (
            self.model.art.n_layers,
            self.model.art.n_heads,
            self.model.art.head_dim,
        );
        let vocab = self.model.art.vocab;
        for (slot, r) in admitted {
            let len = r.prompt.len();
            // Extract this lane's K/V as [l, h, len, dh].
            let mut k = vec![0.0f32; l * h * len * dh];
            let mut v = vec![0.0f32; l * h * len * dh];
            for li in 0..l {
                for hi in 0..h {
                    for t in 0..len {
                        let src = ((((li * b) + slot) * h + hi) * p + t) * dh;
                        let dst = ((li * h + hi) * len + t) * dh;
                        k[dst..dst + dh].copy_from_slice(&out.k[src..src + dh]);
                        v[dst..dst + dh].copy_from_slice(&out.v[src..src + dh]);
                    }
                }
            }
            self.cache.insert_seq(r.id, &k, &v, len)?;

            // First generated token from the prefill logits.
            let logits = &out.logits[slot * vocab..(slot + 1) * vocab];
            let first = argmax(logits);
            let now = Instant::now();
            let reserved_pages = self
                .cache
                .pages_for((len + r.max_new_tokens).min(self.model.art.ctx_bucket));
            self.active.insert(
                r.id,
                ActiveSeq {
                    prompt_len: len,
                    max_new: r.max_new_tokens,
                    last_token: first,
                    generated: vec![first],
                    arrival: r.arrival,
                    prefill_started: t0,
                    first_token_at: now,
                    reserved_pages,
                },
            );
            self.metrics.tokens_generated += 1;
        }
        Ok(())
    }

    fn decode_once(&mut self, finished: &mut Vec<FinishedRequest>) -> Result<()> {
        if self.batcher.active_len() == 0 {
            return Ok(());
        }
        let slots: Vec<Option<RequestId>> = self.batcher.slots().to_vec();
        let b = self.model.art.batch;
        let c = self.model.art.ctx_bucket;
        let (l, h, dh) = (
            self.model.art.n_layers,
            self.model.art.n_heads,
            self.model.art.head_dim,
        );
        let vocab = self.model.art.vocab;

        // Gather paged caches into the contiguous decode views.
        self.cache.gather(&slots, c, &mut self.k_buf, &mut self.v_buf)?;

        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        for (bi, slot) in slots.iter().enumerate() {
            if let Some(id) = slot {
                let seq = &self.active[id];
                tokens[bi] = seq.last_token;
                positions[bi] = self.cache.seq_len(*id).unwrap() as i32;
            }
        }

        let t0 = Instant::now();
        let out = self
            .model
            .decode(&tokens, &self.k_buf, &self.v_buf, &positions)?;
        let step_us = t0.elapsed().as_secs_f64() * 1e6;
        self.metrics.decode_steps += 1;
        self.metrics.step_us.push(step_us);

        if self.config.project_hardware {
            self.record_projection(&slots);
        }

        // Per-lane: append fresh KV, sample, check termination.
        let plane = l * h * dh;
        let mut nk = vec![0.0f32; plane];
        let mut nv = vec![0.0f32; plane];
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = *slot else { continue };
            for li in 0..l {
                for hi in 0..h {
                    let src = (((li * b) + bi) * h + hi) * dh;
                    let dst = (li * h + hi) * dh;
                    nk[dst..dst + dh].copy_from_slice(&out.new_k[src..src + dh]);
                    nv[dst..dst + dh].copy_from_slice(&out.new_v[src..src + dh]);
                }
            }
            self.cache.append_token(id, &nk, &nv)?;

            let seq = self.active.get_mut(&id).unwrap();
            let logits = &out.logits[bi * vocab..(bi + 1) * vocab];
            let next = argmax(logits);
            seq.generated.push(next);
            seq.last_token = next;
            self.metrics.tokens_generated += 1;

            let cache_len = self.cache.seq_len(id).unwrap();
            let reason = if seq.generated.len() >= seq.max_new {
                Some(FinishReason::Length)
            } else if cache_len >= c {
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            if let Some(reason) = reason {
                let seq = self.active.remove(&id).unwrap();
                self.reserved_pages -= seq.reserved_pages;
                let now = Instant::now();
                finished.push(FinishedRequest {
                    id,
                    prompt_len: seq.prompt_len,
                    output: seq.generated,
                    reason,
                    queue_s: (seq.prefill_started - seq.arrival).as_secs_f64(),
                    prefill_s: (seq.first_token_at - seq.prefill_started)
                        .as_secs_f64(),
                    decode_s: (now - seq.first_token_at).as_secs_f64(),
                });
                self.batcher.release(id);
                self.cache.free_seq(id);
                self.metrics.requests_finished += 1;
            }
        }
        Ok(())
    }

    /// Project this step's (ragged) attention batch onto the A100 model:
    /// what would LeanAttention vs FlashDecoding cost on real hardware?
    fn record_projection(&mut self, slots: &[Option<RequestId>]) {
        let lens: Vec<u32> = slots
            .iter()
            .flatten()
            .filter_map(|id| self.cache.seq_len(*id))
            .map(|l| l as u32)
            .collect();
        if lens.is_empty() {
            return;
        }
        let problem =
            DecodeProblem::ragged(self.model.art.n_heads, lens, self.model.art.head_dim);
        let la = simulate(&problem, Strategy::StreamK, &self.arch);
        let fd = simulate(
            &problem,
            Strategy::fixed_split_auto(&problem, self.arch.num_sms),
            &self.arch,
        );
        let layers = self.model.art.n_layers as f64;
        self.metrics.projected_lean_us.push(la.latency_us * layers);
        self.metrics.projected_fd_us.push(fd.latency_us * layers);
        self.metrics.projected_occupancy.push(la.occupancy);
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -5.0]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    // Engine integration tests (need artifacts + PJRT) live in
    // rust/tests/engine_e2e.rs.
}
