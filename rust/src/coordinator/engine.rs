//! The decode-serving engine: continuous batching over the PJRT model
//! artifacts with a paged KV cache, a deterministic logits-sampling
//! pipeline, a radix prefix cache with copy-on-write page sharing, a
//! zero-copy `fork` entry point for parallel sampling, and a per-step
//! LeanAttention hardware projection.
//!
//! One `step()` is one Orca-style iteration: admit waiting requests into
//! free slots (batch prefill), then run one decode step for every active
//! sequence. Python never runs here — both phases execute AOT-compiled
//! HLO through the PJRT CPU client.
//!
//! **Shared-prefix serving.** Prompts are probed against a
//! [`super::radix::RadixPrefixIndex`]; matched full pages are shared by
//! reference ([`PagedKvCache::insert_seq_shared`]) instead of duplicated,
//! which shrinks both the admission footprint (more concurrent sequences
//! fit) and the modeled decode bandwidth (the per-step cascade projection
//! streams each shared prefix once per group). Every admitted prompt's
//! full pages are registered back into the index so later requests can
//! share them; under memory pressure the index evicts cold pages nobody
//! else references.
//!
//! **Parallel sampling.** [`Engine::fork`] clones a live sequence into
//! `n` siblings purely by KV page refcounts (zero page copies at fork
//! time; the shared partial last page is copy-on-write cloned lazily as
//! holders diverge). Each sibling resamples the parent's pending token
//! with its own deterministic RNG, the family's full-page history is
//! registered in the radix index, and the decode loop's prefix grouping
//! streams the shared history once per group — generated sharing rides
//! the same cascade machinery as shared prompts.

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::obs::balance::plan_balance;
use crate::obs::{
    attrib, Attrs, CacheReport, DriftDetector, FlightRecorder, FlightSnapshot,
    FlightTrigger, MetricsSnapshot, Phase, TimelineRecorder, Tracer, Watchdog,
};
use crate::partition::cascade::{CascadeProblem, PrefixGroup};
use crate::partition::plan::{build_plan, DecodeProblem, Strategy};
use crate::runtime::{Manifest, ModelRuntime, Runtime};
use crate::sampling::{sample_token, seq_rng, ForkTree, SamplingParams};
use crate::sim::cascade::simulate_cascade;
use crate::sim::{effective_slots, simulate, CostCoefficients, GpuArch};
use crate::sparse::{advance_rope, selected_tokens, SparsePolicy};
use crate::spec::{verify_chain, AdaptiveK, DraftKind, DraftSource};
use crate::util::rng::Rng;

use super::batcher::ContinuousBatcher;
use super::kv_cache::PagedKvCache;
use super::metrics::{GatherKind, Metrics};
use super::radix::{PrefixMatch, RadixPrefixIndex};
use super::request::{FinishReason, FinishedRequest, Request, RequestId};

/// The sampled online invariant audit: which consistency checks run
/// and how often. The checks are the debug-build validators promoted to
/// a production sampling plan — cheap enough to leave on in serving,
/// thorough enough to catch refcount leaks and radix/cache drift the
/// moment they happen instead of steps later.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AuditPlan {
    /// Run the audit every N engine steps; 0 disables sampling
    /// (explicit [`Engine::run_audit`] calls still work).
    pub every: usize,
    /// Page-statistics-vs-data check ([`PagedKvCache::validate_page_meta`]).
    pub page_meta: bool,
    /// Free-list integrity (entries unique, in range, refcount zero,
    /// and jointly exhaustive over zero-ref pages).
    pub free_list: bool,
    /// Refcount exactness: sequence holders plus radix-index holders
    /// account for every page reference, page by page.
    pub refcounts: bool,
    /// Radix→cache consistency: every indexed page is live.
    pub radix: bool,
}

impl AuditPlan {
    /// All checks, sampled every `every` steps.
    pub fn every(every: usize) -> AuditPlan {
        AuditPlan { every, page_meta: true, free_list: true, refcounts: true, radix: true }
    }

    /// No sampling (the default).
    pub fn disabled() -> AuditPlan {
        AuditPlan { every: 0, ..AuditPlan::every(0) }
    }

    pub fn is_enabled(&self) -> bool {
        self.every > 0
    }
}

impl Default for AuditPlan {
    fn default() -> Self {
        AuditPlan::disabled()
    }
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Model name in the artifact manifest (`tiny`, `small`, ...).
    pub model: String,
    /// KV-cache pages to allocate.
    pub cache_pages: usize,
    /// Tokens per KV page.
    pub page_tokens: usize,
    /// Record per-step LeanAttention-vs-FlashDecoding GPU projections.
    pub project_hardware: bool,
    /// Share prompt-prefix KV pages across requests via the radix index.
    pub enable_prefix_cache: bool,
    /// Default logits pipeline for `submit` (greedy unless overridden
    /// per request via [`Engine::submit_with`]).
    pub sampling: SamplingParams,
    /// Seed of the per-sequence sampling RNGs; with a fixed seed every
    /// generation — including forked best-of-n/beam candidates — is
    /// bit-reproducible.
    pub seed: u64,
    /// Draft tokens verified per decode step (0 disables speculative
    /// decoding). Requires an artifact set with a verify step; without
    /// one the engine degrades to plain one-token decode. The committed
    /// stream is bit-identical either way — speculation only changes
    /// how many verify passes it takes.
    pub spec_k: usize,
    /// Draft source for speculative decoding (n-gram self-drafting needs
    /// no second model).
    pub spec_draft: DraftKind,
    /// Adapt each sequence's draft length from its running acceptance
    /// rate (EWMA over verify passes) instead of the fixed `spec_k`: a
    /// low-acceptance stream converges to 1-draft probes. The committed
    /// stream is unchanged — acceptance is exact for any draft length.
    pub adaptive_spec: bool,
    /// Sparse long-context decode: score and prune context *pages* before
    /// each decode step (Quest-style per-page upper bounds over the
    /// paged cache's key statistics). `None` streams dense.
    pub sparse: Option<SparsePolicy>,
    /// Structured-tracer ring capacity in events; `0` leaves the tracer
    /// disabled (near-zero overhead on every instrumented hot path).
    pub trace_capacity: usize,
    /// Sampled online invariant audits (`serve --audit-every`); the
    /// default plan never runs.
    pub audit: AuditPlan,
    /// Directory for anomaly flight-recorder bundles; `None` disables
    /// the recorder (triggers are not even evaluated into bundles).
    pub flight_dir: Option<String>,
    /// Watchdog stall threshold in consecutive progress-free steps;
    /// 0 disables the watchdog (always healthy).
    pub watchdog_stall_steps: u64,
    /// Flight trigger: prefix-index pages evicted within one step that
    /// count as an eviction storm (0 disables the trigger).
    pub eviction_storm_pages: usize,
    /// Flight trigger: finished-request end-to-end latency (ms) above
    /// which a step records an SLO-breach bundle (0 disables).
    pub flight_slo_ms: f64,
    /// Online cost-model drift detection (`serve --drift-limit`):
    /// relative-error EWMA bound above which a sustained breach fires
    /// the flight recorder's `drift` trigger. 0 disables the detector.
    pub drift_limit: f64,
    /// Calibrated coefficients the drift detector judges (`serve
    /// --drift-calibration <calibrate json>`); `None` falls back to
    /// [`CostCoefficients::nominal`] — the detector's warmup gain
    /// absorbs absolute scale either way.
    pub drift_coefficients: Option<CostCoefficients>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "tiny".into(),
            cache_pages: 256,
            page_tokens: 16,
            project_hardware: true,
            enable_prefix_cache: true,
            sampling: SamplingParams::default(),
            seed: 0,
            spec_k: 0,
            spec_draft: DraftKind::NGram,
            adaptive_spec: false,
            sparse: None,
            trace_capacity: 0,
            audit: AuditPlan::disabled(),
            flight_dir: None,
            watchdog_stall_steps: 0,
            eviction_storm_pages: 64,
            flight_slo_ms: 0.0,
            drift_limit: 0.0,
            drift_coefficients: None,
        }
    }
}

struct ActiveSeq {
    prompt_len: usize,
    max_new: usize,
    last_token: i32,
    generated: Vec<i32>,
    /// Prompt + sampled tokens (the repetition-penalty history; its
    /// first `cache.seq_len` entries are KV-backed, the final entry is
    /// the pending token whose KV lands next step).
    tokens: Vec<i32>,
    /// Per-token logprob trace under the processed distribution.
    logprobs: Vec<f32>,
    /// Running sum of `logprobs` (the best-of-n / beam score).
    cum_logprob: f64,
    /// Raw logits of the most recent sampling step — what a fork
    /// sibling resamples its divergent pending token from.
    last_logits: Vec<f32>,
    /// This sequence's sampling pipeline and private RNG.
    params: SamplingParams,
    rng: Rng,
    /// The sequence this one was forked off, if any.
    parent: Option<RequestId>,
    arrival: Instant,
    prefill_started: Instant,
    first_token_at: Instant,
    /// Fresh KV pages reserved for this request's full budget at
    /// admission (cached prefix pages are excluded — the index holds
    /// those).
    reserved_pages: usize,
    /// Of this request's pages, how many the prefix index newly
    /// registered (they outlive the request, so its release returns
    /// `reserved_pages - index_kept` to the committed-pages pool).
    index_kept: usize,
    /// This sequence's leading full KV pages (shared prefix pages it
    /// references + its own prompt pages). Sequences whose runs share a
    /// leading segment physically share those pages and form a cascade
    /// prefix group — including the request that populated the index,
    /// not just later matchers. Every listed page is in the sequence's
    /// own page list, so it stays referenced while the request is active.
    prefix_pages: Vec<usize>,
    /// Acceptance-aware draft-length controller (consulted only when
    /// [`EngineConfig::adaptive_spec`] is set).
    spec_ctrl: AdaptiveK,
}

/// One decode step's gathered shapes: the contiguous K/V views land in
/// the engine's reusable buffers; this carries what the artifact and the
/// hardware projection consume alongside them.
struct StepViews {
    /// Per-live-lane context lengths for the projection (compacted to the
    /// selected tokens when the sparse policy engages).
    lens: Vec<u32>,
    /// Shared-prefix groups over live-lane indices.
    groups: Vec<PrefixGroup>,
    /// Per-slot cached-token counts the artifact consumes: the number of
    /// valid rows in the gathered view and the fresh token's row index.
    /// Equal to the true cache length on dense steps; smaller on sparse
    /// steps (readers bound themselves by this, so pruned pages are
    /// invisible to the kernel).
    positions: Vec<i32>,
}

/// A single-replica serving engine.
pub struct Engine {
    pub config: EngineConfig,
    model: ModelRuntime,
    cache: PagedKvCache,
    batcher: ContinuousBatcher,
    active: HashMap<RequestId, ActiveSeq>,
    prefix_index: RadixPrefixIndex,
    fork_tree: ForkTree,
    /// Speculative draft source (used when `config.spec_k > 0`).
    drafter: Box<dyn DraftSource>,
    pub metrics: Metrics,
    /// Structured step tracer (disabled unless `config.trace_capacity > 0`).
    pub tracer: Tracer,
    /// Per-request lifecycle timelines, fed at every finish site.
    pub timelines: TimelineRecorder,
    /// Step-progress heartbeat (disabled unless
    /// `config.watchdog_stall_steps > 0`).
    watchdog: Watchdog,
    /// Anomaly post-mortem recorder (enabled by `config.flight_dir`).
    flight: Option<FlightRecorder>,
    /// Online cost-model drift detector (enabled by
    /// `config.drift_limit > 0`).
    drift: Option<DriftDetector>,
    /// Wall time of the current step's gather phase, microseconds (the
    /// gather half of the drift detector's measured step time; written
    /// by [`Engine::gather_step_views`] only while the detector is on).
    last_gather_us: f64,
    /// Engine iterations taken ([`Engine::step`] calls) — the audit
    /// sampling clock and the step stamped into flight bundles.
    steps: u64,
    /// Prefix-index pages evicted during the current step (the
    /// eviction-storm trigger input; reset at every step entry).
    evicted_this_step: usize,
    /// Engine bring-up time: the wall clock behind the SLO report text
    /// frozen into flight bundles.
    started: Instant,
    arch: GpuArch,
    next_id: RequestId,
    /// Pages committed to being (or becoming) allocated: the prefix
    /// index's pages plus every active request's fresh-page budget.
    /// Admission keeps `committed + need <= total`, so same-wave
    /// admissions and later decode appends can never run the cache dry
    /// mid-flight.
    committed_pages: usize,
    // reusable gather buffers (hot path: no per-step allocation)
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
}

impl Engine {
    /// Load artifacts and bring up the engine.
    pub fn new(runtime: &Rc<Runtime>, manifest: &Manifest, config: EngineConfig) -> Result<Engine> {
        if let Some(p) = &config.sparse {
            p.validate()?;
        }
        let model = ModelRuntime::load(runtime, manifest, &config.model)
            .with_context(|| format!("load model {:?}", config.model))?;
        let art = &model.art;
        // The cache stores K/V at kv-head granularity: under GQA this is
        // where the h/h_kv byte shrink comes from (n_kv_heads == n_heads
        // for ungrouped models).
        let cache = PagedKvCache::new(
            art.n_layers,
            art.n_kv_heads,
            art.head_dim,
            config.page_tokens,
            config.cache_pages,
        );
        let batcher = ContinuousBatcher::new(art.batch);
        let prefix_index = RadixPrefixIndex::new(config.page_tokens);
        let cache_elems = model.cache_elems();
        let drafter = config.spec_draft.build(art.vocab, config.seed);
        let tracer = if config.trace_capacity > 0 {
            Tracer::enabled(config.trace_capacity)
        } else {
            Tracer::disabled()
        };
        let mut metrics = Metrics::default();
        metrics.gqa.kv_heads = art.n_kv_heads;
        metrics.gqa.group_size = art.n_heads / art.n_kv_heads;
        let watchdog = Watchdog::new(config.watchdog_stall_steps);
        let flight = config.flight_dir.as_ref().map(FlightRecorder::new);
        let drift = (config.drift_limit > 0.0).then(|| {
            DriftDetector::new(
                config
                    .drift_coefficients
                    .unwrap_or_else(CostCoefficients::nominal),
                config.drift_limit,
            )
        });
        Ok(Engine {
            config,
            model,
            cache,
            batcher,
            active: HashMap::new(),
            prefix_index,
            fork_tree: ForkTree::new(),
            drafter,
            metrics,
            tracer,
            timelines: TimelineRecorder::default(),
            watchdog,
            flight,
            drift,
            last_gather_us: 0.0,
            steps: 0,
            evicted_this_step: 0,
            started: Instant::now(),
            arch: GpuArch::a100(),
            next_id: 1,
            committed_pages: 0,
            k_buf: vec![0.0; cache_elems],
            v_buf: vec![0.0; cache_elems],
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model.art.name
    }

    pub fn batch_size(&self) -> usize {
        self.model.art.batch
    }

    pub fn ctx_bucket(&self) -> usize {
        self.model.art.ctx_bucket
    }

    /// Query heads per layer.
    pub fn query_heads(&self) -> usize {
        self.model.art.n_heads
    }

    /// KV heads per layer — the cache/gather granularity (== query heads
    /// for ungrouped models).
    pub fn kv_heads(&self) -> usize {
        self.model.art.n_kv_heads
    }

    pub fn prefill_bucket(&self) -> usize {
        self.model.art.prefill_bucket
    }

    pub fn waiting(&self) -> usize {
        self.batcher.waiting_len()
    }

    pub fn active(&self) -> usize {
        self.batcher.active_len()
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.is_idle()
    }

    /// Pages currently pinned by the radix prefix index.
    pub fn prefix_index_pages(&self) -> usize {
        self.prefix_index.num_pages()
    }

    /// KV pages currently holding data (shared pages counted once).
    pub fn kv_used_pages(&self) -> usize {
        self.cache.used_pages()
    }

    /// Free batch slots available to admissions and forks.
    pub fn free_slots(&self) -> usize {
        self.batcher.free_slots()
    }

    /// Whether `id` is resident in a batch slot right now.
    pub fn is_active_seq(&self, id: RequestId) -> bool {
        self.active.contains_key(&id)
    }

    /// Cumulative logprob of a live sequence's sampled tokens.
    pub fn cum_logprob(&self, id: RequestId) -> Option<f64> {
        self.active.get(&id).map(|s| s.cum_logprob)
    }

    /// Tokens generated so far by a live sequence.
    pub fn generated_len(&self, id: RequestId) -> Option<usize> {
        self.active.get(&id).map(|s| s.generated.len())
    }

    /// Fork lineage of the engine's sequences.
    pub fn fork_tree(&self) -> &ForkTree {
        &self.fork_tree
    }

    /// Longest prefix of `prompt` (in tokens) this engine's radix index
    /// currently holds, without touching LRU state — the router's
    /// prefix-affinity probe.
    pub fn peek_prefix_tokens(&self, prompt: &[i32]) -> usize {
        if !self.config.enable_prefix_cache {
            return 0;
        }
        self.prefix_index.peek(prompt).tokens
    }

    /// Submit a request with the engine's default sampling parameters.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<RequestId> {
        let params = self.config.sampling.clone();
        self.submit_with(prompt, max_new_tokens, params)
    }

    /// Submit a request with explicit sampling parameters; returns its
    /// id. The prompt must fit the prefill bucket and the vocab, and the
    /// generation budget must be at least one token (prefill always
    /// produces one, so `max_new_tokens = 0` has no meaningful contract
    /// and is rejected).
    pub fn submit_with(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<RequestId> {
        params.validate()?;
        ensure!(max_new_tokens >= 1, "max_new_tokens must be >= 1");
        ensure!(
            !prompt.is_empty() && prompt.len() <= self.model.art.prefill_bucket,
            "prompt length {} outside [1, {}]",
            prompt.len(),
            self.model.art.prefill_bucket
        );
        ensure!(
            prompt.iter().all(|&t| t >= 0 && (t as usize) < self.model.art.vocab),
            "token outside vocab"
        );
        // A request whose full budget can never fit would deadlock the
        // FCFS queue — reject it up front. The budget includes the
        // speculative draft-block overhang: verify passes append the
        // whole block before rolling rejects back.
        let budget = (prompt.len() + max_new_tokens + self.spec_overhang())
            .min(self.model.art.ctx_bucket);
        ensure!(
            self.cache.pages_for(budget) <= self.cache.total_pages(),
            "request budget of {budget} tokens exceeds total KV capacity"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.batcher
            .enqueue(Request::new(id, prompt, max_new_tokens).with_params(params));
        Ok(id)
    }

    /// One engine iteration: admissions (+ batched prefill) and one decode
    /// step. Returns requests that finished during this iteration.
    pub fn step(&mut self) -> Result<Vec<FinishedRequest>> {
        self.tracer.advance_step();
        self.steps += 1;
        self.evicted_this_step = 0;
        let mut finished = Vec::new();
        self.admit_and_prefill(&mut finished)?;
        self.decode_once(&mut finished)?;
        self.observe_step(&finished)?;
        Ok(finished)
    }

    /// Post-step health pass: advance the heat clock, run the sampled
    /// invariant audit when due, beat the watchdog with the engine's
    /// progress counter, and evaluate every flight trigger against this
    /// step's outcome.
    fn observe_step(&mut self, finished: &[FinishedRequest]) -> Result<()> {
        self.cache.heat_tick();

        if self.config.audit.is_enabled() && self.steps % self.config.audit.every as u64 == 0 {
            let failures = self.run_audit();
            if !failures.is_empty() {
                self.record_flight(FlightTrigger::AuditFailure)?;
            }
        }

        // Tokens plus prefill calls: any counter that moves whenever the
        // engine does useful work serves as the heartbeat's progress.
        let progress = (self.metrics.tokens_generated + self.metrics.prefill_calls) as u64;
        if self.watchdog.beat(progress).is_some() {
            self.record_flight(FlightTrigger::WatchdogStall)?;
        }

        if self.config.eviction_storm_pages > 0
            && self.evicted_this_step >= self.config.eviction_storm_pages
        {
            self.record_flight(FlightTrigger::EvictionStorm)?;
        }

        if self.config.flight_slo_ms > 0.0 {
            let slo_s = self.config.flight_slo_ms / 1e3;
            if finished.iter().any(|f| f.queue_s + f.prefill_s + f.decode_s > slo_s) {
                self.record_flight(FlightTrigger::SloBreach)?;
            }
        }

        // One flight bundle per sustained cost-model drift event: the
        // detector latches a pending breach when its error EWMA stays
        // over the limit for PATIENCE steps, and `take_breach` consumes
        // it exactly once.
        let drift_breach = match self.drift.as_mut() {
            Some(d) => d.take_breach(),
            None => false,
        };
        if drift_breach {
            self.record_flight(FlightTrigger::Drift)?;
        }
        Ok(())
    }

    /// Run the configured invariant audit once, unconditionally: page
    /// statistics against the stored data, free-list integrity,
    /// refcount exactness (sequence holders plus radix-index holders
    /// account for every page reference, page by page), and radix→cache
    /// consistency (every indexed page is live). Returns the violations
    /// — empty on a clean pass — and folds pass/fail/duration into the
    /// audit counters.
    pub fn run_audit(&mut self) -> Vec<String> {
        let plan = self.config.audit;
        let t0 = Instant::now();
        let mut failures = Vec::new();
        if plan.page_meta {
            if let Err(e) = self.cache.validate_page_meta() {
                failures.push(format!("page_meta: {e:#}"));
            }
        }
        if plan.free_list {
            if let Err(e) = self.cache.audit_free_list() {
                failures.push(format!("free_list: {e:#}"));
            }
        }
        if plan.refcounts || plan.radix {
            let mut expect = self.cache.seq_page_refs();
            for p in self.prefix_index.pages() {
                match expect.get_mut(p) {
                    Some(r) => *r += 1,
                    None => failures.push(format!("radix: indexed page {p} out of range")),
                }
                if plan.radix && self.cache.page_ref(p) == 0 {
                    failures.push(format!("radix: indexed page {p} is not live"));
                }
            }
            if plan.refcounts {
                for (p, &want) in expect.iter().enumerate() {
                    let got = self.cache.page_ref(p);
                    if got != want {
                        failures.push(format!(
                            "refcount: page {p} holds {got} refs, holders account for {want}"
                        ));
                    }
                }
            }
        }
        self.metrics.audit.runs += 1;
        self.metrics.audit.failures += failures.len();
        self.metrics.audit.audit_us += t0.elapsed().as_secs_f64() * 1e6;
        failures
    }

    /// Freeze the live observability state into a post-mortem bundle
    /// (no-op without a flight dir). Every part is rendered before the
    /// recorder is touched so the bundle is a consistent cut.
    fn record_flight(&mut self, trigger: FlightTrigger) -> Result<()> {
        if self.flight.is_none() {
            return Ok(());
        }
        let trace = self.tracer.export_chrome_trace();
        let metrics = self.snapshot().to_json();
        let cache_report = self.cache_report(8).to_json();
        let slo_ms = if self.config.flight_slo_ms > 0.0 {
            self.config.flight_slo_ms
        } else {
            1000.0
        };
        let slo_text = self
            .timelines
            .slo_report(slo_ms, self.started.elapsed().as_secs_f64())
            .render();
        let snap = FlightSnapshot {
            trace: &trace,
            metrics: &metrics,
            cache_report: &cache_report,
            slo_text: &slo_text,
        };
        let step = self.steps;
        self.flight
            .as_mut()
            .unwrap()
            .record(trigger, step, &snap)
            .context("record flight bundle")?;
        Ok(())
    }

    /// The KV-cache introspection report over the live pool, heat
    /// tracker and (when prefix caching is on) the radix-index shape,
    /// keeping the `top_k` hottest pages.
    pub fn cache_report(&self, top_k: usize) -> CacheReport {
        let radix = self
            .config
            .enable_prefix_cache
            .then(|| self.prefix_index.stats());
        self.cache.report(radix, top_k)
    }

    /// Flight bundle directories written so far.
    pub fn flight_bundles(&self) -> u64 {
        self.flight.as_ref().map_or(0, |f| f.bundles())
    }

    /// `false` from a fired watchdog stall until progress resumes.
    pub fn healthy(&self) -> bool {
        self.watchdog.healthy()
    }

    /// Point-in-time sample of every documented serving counter plus the
    /// engine's live gauges — the one struct both the Prometheus text
    /// and versioned-JSON exporters serialize.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut s = self.metrics.snapshot();
        s.gauge(
            "kv_pages_used",
            self.cache.used_pages() as f64,
            "KV pages currently holding data (shared pages counted once).",
        );
        s.gauge(
            "kv_pages_total",
            self.cache.total_pages() as f64,
            "KV pages allocated to the cache.",
        );
        s.gauge(
            "prefix_index_pages",
            self.prefix_index.num_pages() as f64,
            "Pages pinned by the radix prefix index.",
        );
        s.gauge(
            "requests_waiting",
            self.batcher.waiting_len() as f64,
            "Requests queued for admission.",
        );
        s.gauge(
            "requests_active",
            self.batcher.active_len() as f64,
            "Sequences resident in batch slots.",
        );
        s.gauge(
            "requests_peak_waiting",
            self.batcher.take_peak_waiting() as f64,
            "Peak admission-queue depth since the previous snapshot.",
        );
        s.counter(
            "requests_observed_total",
            self.timelines.requests() as f64,
            "Request lifecycles folded into the timeline recorder.",
        );
        s.counter(
            "trace_events_dropped_total",
            self.tracer.dropped() as f64,
            "Trace events dropped to ring overflow.",
        );
        let heat = self.cache.heat();
        s.counter(
            "kv_gather_page_touches_total",
            heat.gather_total() as f64,
            "Page touches recorded at the cache's gather sites (flat, shared, selected).",
        );
        s.counter(
            "kv_append_page_touches_total",
            heat.append_total() as f64,
            "Page touches recorded at the cache's token-append site.",
        );
        s.counter(
            "kv_select_page_touches_total",
            heat.select_total() as f64,
            "Page touches recorded by sparse page selection.",
        );
        s.counter(
            "kv_cow_clones_total",
            heat.cow_clones() as f64,
            "Copy-on-write page clones performed by the cache.",
        );
        let report = self.cache_report(0);
        s.gauge(
            "kv_pool_fragmentation",
            report.pool.fragmentation,
            "Free-pool fragmentation: 1 - largest free run / free pages.",
        );
        s.gauge(
            "radix_max_depth",
            report.radix.as_ref().map_or(0.0, |r| r.max_depth as f64),
            "Deepest chain in the radix prefix index, in pages.",
        );
        s.gauge(
            "engine_healthy",
            if self.watchdog.healthy() { 1.0 } else { 0.0 },
            "1 while the watchdog sees step progress; 0 during a stall.",
        );
        s.counter(
            "watchdog_stalls_total",
            self.watchdog.stalls() as f64,
            "Watchdog stall events fired.",
        );
        s.counter(
            "flight_bundles_total",
            self.flight_bundles() as f64,
            "Flight-recorder post-mortem bundles written to disk.",
        );
        s.counter(
            "flight_triggers_total",
            self.flight.as_ref().map_or(0, |f| f.triggers()) as f64,
            "Flight trigger firings observed (written plus cap-suppressed).",
        );
        s
    }

    /// Drive until every submitted request completes.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    /// Fork a live sequence into `n` siblings that share its **entire**
    /// KV history by reference — zero page copies at fork time (pure
    /// refcounts via [`PagedKvCache::fork_seq`]; the shared partial last
    /// page, if any, is copy-on-write cloned lazily on each holder's
    /// next append). Each sibling resamples the parent's pending token
    /// from the stored last-step logits with its own deterministic RNG,
    /// so candidates diverge immediately while physically sharing every
    /// decoded page. Siblings enter free batch slots directly (no FCFS
    /// queue), the parent's full-page history is registered in the radix
    /// index, and the family's shared leading page run is exposed to the
    /// decode loop's prefix grouping — the next decode step streams the
    /// shared history once per group through the cascade gather instead
    /// of once per sibling.
    ///
    /// Returns the sibling ids. Fails (leaving the engine untouched)
    /// when `n` free slots or the siblings' KV page reservations are not
    /// available.
    pub fn fork(&mut self, seq: RequestId, n: usize) -> Result<Vec<RequestId>> {
        ensure!(n >= 1, "fork needs n >= 1");
        ensure!(
            self.active.contains_key(&seq),
            "sequence {seq} is not an active sequence"
        );
        let cache_len = self.cache.seq_len(seq).expect("active sequence has cache");
        let pages = self.cache.seq_pages(seq).expect("active").to_vec();
        let full_pages = cache_len / self.config.page_tokens;
        let free = self.batcher.free_slots();
        ensure!(free >= n, "fork needs {n} free batch slots, {free} available");

        // Snapshot the parent state every sibling clones.
        let parent = &self.active[&seq];
        let p_prompt_len = parent.prompt_len;
        let p_max_new = parent.max_new;
        let p_generated = parent.generated.clone();
        let p_tokens = parent.tokens.clone();
        let p_logprobs = parent.logprobs.clone();
        let p_cum = parent.cum_logprob;
        let p_logits = parent.last_logits.clone();
        let p_params = parent.params.clone();
        // Siblings inherit the parent's acceptance estimate: its history
        // is the best predictor of theirs at the fork point.
        let p_ctrl = parent.spec_ctrl.clone();

        // Reserve fresh pages for every sibling's remaining budget: its
        // final context minus the full pages it shares forever (the
        // shared partial last page is replaced by a COW clone out of
        // this same budget). Budgets include the speculative draft-block
        // overhang, like admission.
        let budget = (p_prompt_len + p_max_new + self.spec_overhang())
            .min(self.model.art.ctx_bucket);
        let need = self.cache.pages_for(budget).saturating_sub(full_pages);
        let total = self.cache.total_pages();
        ensure!(
            self.committed_pages + n * need <= total,
            "KV cache cannot hold {n} fork siblings: need {} fresh pages, {} uncommitted",
            n * need,
            total - self.committed_pages
        );

        // Register the parent's KV-backed history (prompt + decoded
        // tokens, full pages only) in the radix index: future prompts
        // sharing the history can reuse it, and the family's pages gain
        // the same LRU protection as shared prompts. These pages came
        // out of the parent's reservation, so keeping them indexed means
        // the parent's release must not decommit them.
        if self.config.enable_prefix_cache && full_pages > 0 {
            let fresh = self.prefix_index.insert(&p_tokens[..cache_len], &pages);
            for &pg in &fresh {
                self.cache.retain_page(pg)?;
            }
            self.active.get_mut(&seq).unwrap().index_kept += fresh.len();
        }

        // The family's physically-shared leading full pages: the decode
        // loop groups sequences whose runs share a leading segment into
        // one cascade prefix group, parent included.
        let prefix_run: Vec<usize> = pages[..full_pages].to_vec();

        let now = Instant::now();
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            let id = self.next_id;
            self.next_id += 1;
            self.cache.fork_seq(seq, id)?;
            self.batcher.occupy(id).expect("free slots were checked above");
            // Resample the pending token with the sibling's own RNG:
            // divergence starts at the fork token, not one step later.
            let mut rng = seq_rng(self.config.seed, id);
            let s = sample_token(&p_logits, &p_tokens[..cache_len], &p_params, &mut rng);
            let mut generated = p_generated.clone();
            *generated.last_mut().unwrap() = s.token;
            let mut tokens = p_tokens.clone();
            *tokens.last_mut().unwrap() = s.token;
            let mut logprobs = p_logprobs.clone();
            let cum_logprob =
                p_cum - f64::from(*logprobs.last().unwrap()) + f64::from(s.logprob);
            *logprobs.last_mut().unwrap() = s.logprob;
            self.active.insert(
                id,
                ActiveSeq {
                    prompt_len: p_prompt_len,
                    max_new: p_max_new,
                    last_token: s.token,
                    generated,
                    tokens,
                    logprobs,
                    cum_logprob,
                    last_logits: p_logits.clone(),
                    params: p_params.clone(),
                    rng,
                    parent: Some(seq),
                    arrival: now,
                    prefill_started: now,
                    first_token_at: now,
                    reserved_pages: need,
                    index_kept: 0,
                    prefix_pages: prefix_run.clone(),
                    spec_ctrl: p_ctrl.clone(),
                },
            );
            self.fork_tree.register(seq, id, cache_len);
            ids.push(id);
        }
        self.committed_pages += n * need;
        self.active.get_mut(&seq).unwrap().prefix_pages = prefix_run;
        self.metrics.sampling.fork_calls += 1;
        self.metrics.sampling.forked_siblings += n;
        Ok(ids)
    }

    /// Cancel a live sequence (beam pruning): frees its batch slot, KV
    /// pages and reservation, and returns a [`FinishReason::Cancelled`]
    /// record carrying the partial output and logprob trace.
    pub fn cancel(&mut self, id: RequestId) -> Result<FinishedRequest> {
        let seq = self
            .active
            .remove(&id)
            .ok_or_else(|| anyhow!("sequence {id} is not an active sequence"))?;
        self.committed_pages -= seq.reserved_pages - seq.index_kept;
        self.batcher.release(id);
        self.cache.free_seq(id);
        self.fork_tree.remove(id);
        self.metrics.sampling.cancelled += 1;
        self.metrics.requests_finished += 1;
        let now = Instant::now();
        let fr = FinishedRequest {
            id,
            prompt_len: seq.prompt_len,
            output: seq.generated,
            reason: FinishReason::Cancelled,
            queue_s: (seq.prefill_started - seq.arrival).as_secs_f64(),
            prefill_s: (seq.first_token_at - seq.prefill_started).as_secs_f64(),
            decode_s: (now - seq.first_token_at).as_secs_f64(),
            cum_logprob: seq.cum_logprob,
            logprobs: seq.logprobs,
            parent: seq.parent,
        };
        self.timelines.observe(fr.timeline());
        Ok(fr)
    }

    /// Extra KV tokens reserved per request beyond `prompt + max_new`:
    /// a speculative verify pass eagerly appends its whole draft block
    /// (`spec_bucket` rows) before truncating rejects, so admission must
    /// budget for the transient peak — the engine half of
    /// variable-tokens-per-step accounting.
    fn spec_overhang(&self) -> usize {
        if self.config.spec_k == 0 || !self.model.has_verify() {
            0
        } else {
            self.model.art.spec_bucket
        }
    }

    /// Whether this engine actually runs speculative steps (configured
    /// *and* backed by a verify artifact).
    pub fn spec_enabled(&self) -> bool {
        self.config.spec_k > 0 && self.model.has_verify()
    }

    fn admit_and_prefill(&mut self, finished: &mut Vec<FinishedRequest>) -> Result<()> {
        let ctx_cap = self.model.art.ctx_bucket;
        let overhang = self.spec_overhang();
        let budget = move |r: &Request| {
            (r.prompt.len() + r.max_new_tokens + overhang).min(ctx_cap)
        };

        // Under memory pressure, evict cold prefix-index pages nobody
        // else references so the queue head can fit. The head's match is
        // kept (eviction spares those pages) and handed to the admission
        // gate below, saving a redundant trie walk per congested step.
        let mut head_match: Option<PrefixMatch> = None;
        if self.config.enable_prefix_cache
            && self.batcher.free_slots() > 0
            && !self.prefix_index.is_empty()
        {
            if let Some(front) = self.batcher.peek_waiting() {
                let m = self.prefix_index.peek(&front.prompt);
                self.metrics.prefix.lookups += 1;
                let need = self
                    .cache
                    .pages_for(budget(front))
                    .saturating_sub(m.pages.len());
                let available = self
                    .cache
                    .total_pages()
                    .saturating_sub(self.committed_pages);
                if need > available {
                    let cache = &self.cache;
                    // Spare the pages the head request is about to share.
                    let evicted = self.prefix_index.evict_lru(need - available, |p| {
                        cache.page_ref(p) == 1 && !m.pages.contains(&p)
                    });
                    for &p in &evicted {
                        self.cache.release_page(p)?;
                    }
                    self.committed_pages -= evicted.len();
                    self.metrics.prefix.evicted_pages += evicted.len();
                    self.evicted_this_step += evicted.len();
                    if !evicted.is_empty() {
                        self.tracer.instant(
                            Phase::Evict,
                            Attrs { pages: Some(evicted.len()), ..Default::default() },
                        );
                    }
                }
                head_match = Some(m);
            }
        }

        // Admit up to the free slots, gated by KV page availability for
        // the prompt plus the *whole* generation budget (minus pages a
        // cached prefix already provides), reserving as we go. The budget
        // caps at the ctx bucket (generation stops there with ContextFull
        // regardless).
        let cache = &self.cache;
        let prefix_index = &self.prefix_index;
        let use_prefix = self.config.enable_prefix_cache;
        let mut committed = self.committed_pages;
        let total = cache.total_pages();
        let mut needs: Vec<usize> = Vec::new();
        // Gate-time probes of queued/rejected requests count too — the
        // hit rate is per actual index probe, not per admitted request.
        let mut gate_probes = 0usize;
        let admitted = self.batcher.admit(|r| {
            let m = if use_prefix {
                // First gate call is the same head the eviction pass
                // probed; its match is unchanged (eviction spared it).
                head_match.take().unwrap_or_else(|| {
                    gate_probes += 1;
                    prefix_index.peek(&r.prompt)
                })
            } else {
                PrefixMatch::default()
            };
            let need = cache.pages_for(budget(r)).saturating_sub(m.pages.len());
            if committed + need <= total {
                committed += need;
                needs.push(need);
                true
            } else {
                false
            }
        });
        self.committed_pages = committed;
        self.metrics.prefix.lookups += gate_probes;
        if admitted.is_empty() {
            return Ok(());
        }

        let b = self.model.art.batch;
        let p = self.model.art.prefill_bucket;
        let mut tokens = vec![0i32; b * p];
        let mut lengths = vec![1i32; b]; // dummy lanes prefill 1 token
        for (slot, r) in &admitted {
            tokens[slot * p..slot * p + r.prompt.len()].copy_from_slice(&r.prompt);
            lengths[*slot] = r.prompt.len() as i32;
        }

        let t0 = Instant::now();
        let prefill_start = self.tracer.now();
        let out = self.model.prefill(&tokens, &lengths)?;
        self.metrics.prefill_calls += 1;
        self.metrics
            .prefill_us
            .record(t0.elapsed().as_secs_f64() * 1e6);
        self.tracer.record_since(
            Phase::Prefill,
            prefill_start,
            Attrs { k: Some(admitted.len()), ..Default::default() },
        );

        // K/V planes are kv-head granular (h_kv == n_heads when ungrouped).
        let (l, h, dh) = (
            self.model.art.n_layers,
            self.model.art.n_kv_heads,
            self.model.art.head_dim,
        );
        let vocab = self.model.art.vocab;
        for ((slot, r), need) in admitted.into_iter().zip(needs) {
            let len = r.prompt.len();
            // Re-probe the index now: an earlier request in this same
            // admission wave may have just registered the shared prefix,
            // so a cold burst of identical prompts still deduplicates
            // everything after the first. (Admission reserved pages using
            // the pre-wave probe — a larger match here only means fewer
            // fresh pages than reserved, which the finish-time release
            // balances.) This probe is the one that commits to sharing,
            // so it goes through `lookup` to refresh the LRU stamps of
            // the matched chain — `peek` stays reserved for
            // admission-control probes, which must not perturb eviction
            // order. Without the bump here, eviction degrades to
            // insertion order and can evict a hot system prompt.
            let m = if use_prefix {
                self.metrics.prefix.lookups += 1;
                self.prefix_index.lookup(&r.prompt)
            } else {
                PrefixMatch::default()
            };
            // Extract this lane's K/V rows *after* the cached prefix as
            // [l, h, suffix, dh] — the prefix pages are shared, so only
            // the suffix is written into fresh pages.
            let skip = m.tokens;
            let suffix = len - skip;
            let mut k = vec![0.0f32; l * h * suffix * dh];
            let mut v = vec![0.0f32; l * h * suffix * dh];
            for li in 0..l {
                for hi in 0..h {
                    for t in 0..suffix {
                        let src = ((((li * b) + slot) * h + hi) * p + skip + t) * dh;
                        let dst = ((li * h + hi) * suffix + t) * dh;
                        k[dst..dst + dh].copy_from_slice(&out.k[src..src + dh]);
                        v[dst..dst + dh].copy_from_slice(&out.v[src..src + dh]);
                    }
                }
            }
            if skip > 0 {
                self.cache.insert_seq_shared(r.id, &m.pages, &k, &v, suffix)?;
            } else {
                self.cache.insert_seq(r.id, &k, &v, len)?;
            }
            self.tracer.instant(
                Phase::Admit,
                Attrs { seq: Some(r.id), pages: Some(need), ..Default::default() },
            );

            // Account the hit and register this prompt's full pages so
            // later requests can share them.
            let mut index_kept = 0;
            let mut prefix_run = Vec::new();
            if use_prefix {
                if skip > 0 {
                    self.metrics.prefix.hits += 1;
                    self.metrics.prefix.tokens_matched += skip;
                    self.metrics.prefix.pages_shared += m.pages.len();
                    self.metrics.prefix.kv_bytes_deduped +=
                        (m.pages.len() * self.cache.page_bytes()) as u64;
                }
                let pages = self.cache.seq_pages(r.id).unwrap().to_vec();
                let fresh = self.prefix_index.insert(&r.prompt, &pages);
                for &pg in &fresh {
                    self.cache.retain_page(pg)?;
                }
                index_kept = fresh.len();
                // This sequence's leading full pages — shared prefix pages
                // plus the pages it just registered. Every page here is in
                // its own page list (reference held while active), so the
                // cascade grouping below can never see a freed-and-reused
                // page id; and the prefix *owner* participates in groups,
                // not just later matchers.
                let full = (len / self.config.page_tokens).min(pages.len());
                prefix_run = pages[..full].to_vec();
            }

            // First generated token: the sampling pipeline over the
            // prefill logits with this sequence's own deterministic RNG.
            let logits = out.logits[slot * vocab..(slot + 1) * vocab].to_vec();
            let mut rng = seq_rng(self.config.seed, r.id);
            let s = sample_token(&logits, &r.prompt, &r.params, &mut rng);
            let first = s.token;
            let now = Instant::now();
            self.metrics.tokens_generated += 1;

            // A one-token budget is already satisfied by the prefill
            // logits: finish here instead of letting the decode loop push
            // a second token past the budget (`submit` rejects budget 0).
            if r.max_new_tokens <= 1 {
                self.committed_pages -= need - index_kept;
                let fr = FinishedRequest {
                    id: r.id,
                    prompt_len: len,
                    output: vec![first],
                    reason: FinishReason::Length,
                    queue_s: (t0 - r.arrival).as_secs_f64(),
                    prefill_s: (now - t0).as_secs_f64(),
                    decode_s: 0.0,
                    cum_logprob: f64::from(s.logprob),
                    logprobs: vec![s.logprob],
                    parent: None,
                };
                self.timelines.observe(fr.timeline());
                finished.push(fr);
                self.batcher.release(r.id);
                self.cache.free_seq(r.id);
                self.metrics.requests_finished += 1;
                continue;
            }

            let mut tokens = r.prompt;
            tokens.push(first);
            self.active.insert(
                r.id,
                ActiveSeq {
                    prompt_len: len,
                    max_new: r.max_new_tokens,
                    last_token: first,
                    generated: vec![first],
                    tokens,
                    logprobs: vec![s.logprob],
                    cum_logprob: f64::from(s.logprob),
                    last_logits: logits,
                    params: r.params,
                    rng,
                    parent: None,
                    arrival: r.arrival,
                    prefill_started: t0,
                    first_token_at: now,
                    reserved_pages: need,
                    index_kept,
                    prefix_pages: prefix_run,
                    spec_ctrl: AdaptiveK::new(self.config.spec_k),
                },
            );
        }
        Ok(())
    }

    fn decode_once(&mut self, finished: &mut Vec<FinishedRequest>) -> Result<()> {
        if self.batcher.active_len() == 0 {
            return Ok(());
        }
        if self.spec_step_ready() {
            return self.decode_once_spec(finished);
        }
        self.decode_once_plain(finished)
    }

    /// Whether this step can run as one speculative verify pass: spec is
    /// configured, a verify artifact exists, and every live sequence has
    /// room for the whole draft block inside the ctx bucket. Steps near
    /// the bucket end degrade to plain single-token decode, so the
    /// non-speculative finish semantics are preserved exactly.
    fn spec_step_ready(&self) -> bool {
        if self.config.spec_k == 0 || !self.model.has_verify() {
            return false;
        }
        let s = self.model.art.spec_bucket;
        let c = self.model.art.ctx_bucket;
        self.batcher
            .slots()
            .iter()
            .flatten()
            .all(|id| match self.cache.seq_len(*id) {
                Some(len) => len + s <= c,
                None => true,
            })
    }

    /// Score and select each live lane's context pages under the sparse
    /// policy. Returns `None` when the step streams dense: no policy,
    /// every lane below the dense threshold. A policy whose budget covers
    /// every context still routes through the selected-page gather (with
    /// complete selections), which is proven bit-identical to the dense
    /// path — the engine half of the degenerate-sparsity guarantee.
    fn sparse_selections(
        &mut self,
        slots: &[Option<RequestId>],
    ) -> Option<Vec<Vec<usize>>> {
        let policy = self.config.sparse?;
        let mut engaged = false;
        let mut sels: Vec<Vec<usize>> = vec![Vec::new(); slots.len()];
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = slot else { continue };
            let Some((sel, scores)) = self.cache.select_seq_pages(*id, &policy)
            else {
                continue;
            };
            let scored = scores.is_some();
            if let Some(scores) = scores {
                self.metrics.sparse.record_scored_lane(&scores, &sel);
            }
            engaged |= policy.engages(sel.len(), scored);
            sels[bi] = sel;
        }
        engaged.then_some(sels)
    }

    /// Gather the paged caches into the contiguous decode views. Steps
    /// whose lanes share a prefix run take the cascade (Strategy::
    /// Cascade) gather: each shared run is materialized once and
    /// scattered into its member lanes, and the measured dedup is
    /// recorded. Solo steps keep the allocation-free flat gather. When
    /// the sparse policy engages, only each lane's **selected** pages are
    /// materialized (compacted, shared sink runs still deduplicated) and
    /// the returned positions shrink to the compacted lengths.
    ///
    /// The monolithic decode HLO still consumes dense per-lane views,
    /// so on this CPU path the scatter re-expands the runs (segment
    /// allocation + one extra copy per shared run vs the flat gather);
    /// the SharedSegment views are the shape a kernel-level cascade
    /// attention consumes directly, at which point compose_dense
    /// disappears. gather_shared re-derives the same leading-run
    /// grouping as step_prefix_groups from the live page lists (the
    /// physical ground truth); kv_cache_props pins the two paths'
    /// views bit-identical either way.
    fn gather_step_views(&mut self, slots: &[Option<RequestId>]) -> Result<StepViews> {
        let c = self.model.art.ctx_bucket;

        // Drift observations pair the predicted gather+exec work with
        // the measured gather+exec wall time; the timer is independent
        // of the tracer so `--drift-limit` works untraced.
        let drift_t0 = if self.drift.is_some() { Some(Instant::now()) } else { None };

        let select_start = self.tracer.now();
        let sels = self.sparse_selections(slots);
        if self.config.sparse.is_some() {
            self.tracer.record_since(Phase::SparseSelect, select_start, Attrs::default());
        }
        if let Some(sels) = sels {
            let gather_start = self.tracer.now();
            let sg = self.cache.gather_selected(slots, &sels)?;
            sg.compose_dense(c, &mut self.k_buf, &mut self.v_buf)?;
            self.metrics.sparse.selection_steps += 1;
            self.metrics.sparse.gather_bytes_dense += sg.flat_bytes as u64;

            // Compacted per-lane lengths: what the artifact masks to and
            // where the fresh token lands in the packed view. (The fresh
            // token is therefore *rotated* at the compacted index too —
            // a uniform relative-angle shift for the transient query,
            // while the appended K row is advanced back to its true
            // position by the decode loops so the cache never holds a
            // mis-rotated key.)
            let mut lens = Vec::new();
            let mut positions = vec![0i32; slots.len()];
            let mut live_of_slot = vec![usize::MAX; slots.len()];
            let token_bytes = self.cache.token_bytes() as u64;
            let mut sparse_bytes = 0u64;
            for (bi, slot) in slots.iter().enumerate() {
                let Some(id) = slot else { continue };
                let Some(len) = self.cache.seq_len(*id) else { continue };
                let compact = selected_tokens(len, self.config.page_tokens, &sels[bi]);
                // Selected bytes are counted per lane so the sparse
                // ratio isolates pure selection: the cascade dedup of a
                // shared sink run (which the dense path also enjoys) is
                // reported by the cascade gather counters, not here.
                // The count goes through the shared attrib accounting so
                // bench reports and the simulator price the same bytes.
                sparse_bytes += attrib::selected_gather_bytes(
                    len,
                    self.config.page_tokens,
                    &sels[bi],
                    token_bytes as usize,
                );
                live_of_slot[bi] = lens.len();
                lens.push(compact as u32);
                positions[bi] = compact as i32;
            }
            self.metrics.record_gather(GatherKind::Selected, sparse_bytes);
            self.tracer.record_since(
                Phase::Gather,
                gather_start,
                Attrs { bytes: Some(sparse_bytes), ..Default::default() },
            );
            // Shared selected runs (the deduplicated sink pages of a
            // prefix group) become the projection's prefix groups.
            let groups: Vec<PrefixGroup> = sg
                .segments
                .iter()
                .filter(|s| s.lanes.len() >= 2)
                .map(|s| PrefixGroup {
                    prefix_len: s.tokens as u32,
                    members: s
                        .lanes
                        .iter()
                        .map(|&lane| live_of_slot[lane] as u32)
                        .collect(),
                })
                .collect();
            if let Some(t0) = drift_t0 {
                self.last_gather_us = t0.elapsed().as_secs_f64() * 1e6;
            }
            return Ok(StepViews { lens, groups, positions });
        }

        // Detect physically-shared leading page runs once per step: both
        // the gather below and the hardware projection consume them.
        let detect = self.config.enable_prefix_cache || self.config.project_hardware;
        let (lens, groups) = if detect {
            self.step_prefix_groups(slots)
        } else {
            (Vec::new(), Vec::new())
        };
        let gather_start = self.tracer.now();
        let gather_bytes;
        if groups.is_empty() {
            self.cache.gather(slots, c, &mut self.k_buf, &mut self.v_buf)?;
            // Attrib-accounted bytes: same formula the bench reports and
            // the simulator price (tests pin it to the cache's own count).
            let live: Vec<u32> = slots
                .iter()
                .flatten()
                .filter_map(|id| self.cache.seq_len(*id))
                .map(|len| len as u32)
                .collect();
            gather_bytes = attrib::flat_gather_bytes(&live, self.cache.token_bytes());
        } else {
            let sg = self.cache.gather_shared(slots)?;
            sg.compose_dense(c, &mut self.k_buf, &mut self.v_buf)?;
            self.metrics.cascade_gather_steps += 1;
            self.metrics.gather_bytes_flat += sg.flat_bytes as u64;
            self.metrics.gather_bytes_shared += sg.shared_bytes as u64;
            gather_bytes = sg.shared_bytes as u64;
            // gather_shared's physical dedup must equal the attrib
            // prediction over the step's detected prefix groups.
            debug_assert_eq!(
                attrib::flat_gather_bytes(&lens, self.cache.token_bytes()),
                sg.flat_bytes as u64,
            );
            debug_assert_eq!(
                attrib::shared_gather_bytes(&lens, &groups, self.cache.token_bytes()),
                sg.shared_bytes as u64,
            );
        }
        // The gather moved kv-head-granular planes; record_gather scales
        // the dense baseline (one KV head per query head) by group_size.
        let kind = if groups.is_empty() { GatherKind::Flat } else { GatherKind::Shared };
        self.metrics.record_gather(kind, gather_bytes);
        self.tracer.record_since(
            Phase::Gather,
            gather_start,
            Attrs { bytes: Some(gather_bytes), ..Default::default() },
        );
        let mut positions = vec![0i32; slots.len()];
        for (bi, slot) in slots.iter().enumerate() {
            if let Some(id) = slot {
                positions[bi] = self.cache.seq_len(*id).unwrap_or(0) as i32;
            }
        }
        if let Some(t0) = drift_t0 {
            self.last_gather_us = t0.elapsed().as_secs_f64() * 1e6;
        }
        Ok(StepViews { lens, groups, positions })
    }

    fn decode_once_plain(&mut self, finished: &mut Vec<FinishedRequest>) -> Result<()> {
        let slots: Vec<Option<RequestId>> = self.batcher.slots().to_vec();
        let b = self.model.art.batch;
        let c = self.model.art.ctx_bucket;
        // K/V planes are kv-head granular (h_kv == n_heads when ungrouped).
        let (l, h, dh) = (
            self.model.art.n_layers,
            self.model.art.n_kv_heads,
            self.model.art.head_dim,
        );
        let vocab = self.model.art.vocab;

        let views = self.gather_step_views(&slots)?;

        let mut tokens = vec![0i32; b];
        for (bi, slot) in slots.iter().enumerate() {
            if let Some(id) = slot {
                tokens[bi] = self.active[id].last_token;
            }
        }

        let t0 = Instant::now();
        let exec_start = self.tracer.now();
        let out = self
            .model
            .decode(&tokens, &self.k_buf, &self.v_buf, &views.positions)?;
        let step_us = t0.elapsed().as_secs_f64() * 1e6;
        self.metrics.decode_steps += 1;
        self.metrics.step_us.record(step_us);
        let lanes = slots.iter().flatten().count();
        // Work-accounting trace attr: flops are tile-independent, so the
        // span agrees with the projection's plan accounting exactly.
        let exec_flops = (self.tracer.is_enabled() && !views.lens.is_empty()).then(|| {
            let p = DecodeProblem::ragged(
                self.model.art.n_heads,
                views.lens.clone(),
                self.model.art.head_dim,
            )
            .with_kv_heads(self.model.art.n_kv_heads);
            attrib::account_decode_problem(&p).softmax_flops
        });
        self.tracer.record_since(
            Phase::LeanExec,
            exec_start,
            Attrs { k: Some(lanes), flops: exec_flops, ..Default::default() },
        );

        // Online drift check: one (exact work, measured µs) pair per
        // decode step — the serve-time replay of the calibration join.
        // The measured side is gather + decode wall time, matching the
        // byte + flop + tile terms the coefficients price.
        if let Some(d) = self.drift.as_mut() {
            if !views.lens.is_empty() {
                let p = DecodeProblem::ragged(
                    self.model.art.n_heads,
                    views.lens.clone(),
                    self.model.art.head_dim,
                )
                .with_kv_heads(self.model.art.n_kv_heads);
                let work = attrib::account_decode_problem(&p);
                let measured_us = self.last_gather_us + step_us;
                d.observe(&work, measured_us);
            }
            self.metrics.balance.drift_observations = d.observations();
            self.metrics.balance.drift_breaches = d.breaches();
            self.metrics.balance.drift_rel_err = d.rel_err().unwrap_or(0.0);
        }

        if self.config.project_hardware {
            self.record_projection(&views.lens, &views.groups);
        }

        // Per-lane: append fresh KV, sample, check termination.
        let sample_start = self.tracer.now();
        let plane = l * h * dh;
        let mut nk = vec![0.0f32; plane];
        let mut nv = vec![0.0f32; plane];
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = *slot else { continue };
            for li in 0..l {
                for hi in 0..h {
                    let src = (((li * b) + bi) * h + hi) * dh;
                    let dst = (li * h + hi) * dh;
                    nk[dst..dst + dh].copy_from_slice(&out.new_k[src..src + dh]);
                    nv[dst..dst + dh].copy_from_slice(&out.new_v[src..src + dh]);
                }
            }
            // Under sparse selection the artifact rotated this fresh K
            // row at the compacted position; advance it to its true
            // index before it outlives the step in the cache (a zero
            // delta — dense and covering-budget steps — is a no-op, so
            // bit-identity with dense decode is preserved).
            let true_len = self.cache.seq_len(id).unwrap();
            let delta = true_len as f64 - f64::from(views.positions[bi]);
            if delta > 0.0 {
                advance_rope(&mut nk, dh, delta, self.model.art.rope_base);
            }
            if self.cache.append_token(id, &nk, &nv)? {
                self.metrics.prefix.cow_copies += 1;
            }

            let seq = self.active.get_mut(&id).unwrap();
            let logits = &out.logits[bi * vocab..(bi + 1) * vocab];
            let s = sample_token(logits, &seq.tokens, &seq.params, &mut seq.rng);
            seq.generated.push(s.token);
            seq.tokens.push(s.token);
            seq.logprobs.push(s.logprob);
            seq.cum_logprob += f64::from(s.logprob);
            seq.last_token = s.token;
            seq.last_logits.clear();
            seq.last_logits.extend_from_slice(logits);
            self.metrics.tokens_generated += 1;

            let cache_len = self.cache.seq_len(id).unwrap();
            let reason = if seq.generated.len() >= seq.max_new {
                Some(FinishReason::Length)
            } else if cache_len >= c {
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            if let Some(reason) = reason {
                self.finish_seq(id, reason, finished);
            }
        }
        self.tracer.record_since(
            Phase::Sample,
            sample_start,
            Attrs { k: Some(lanes), ..Default::default() },
        );
        Ok(())
    }

    /// Retire a finished sequence from the decode loop: emit its
    /// [`FinishedRequest`], free its batch slot, KV pages and fork
    /// lineage, and return the non-indexed part of its page reservation
    /// to the pool. Shared by the plain and speculative decode paths so
    /// finish semantics can never drift between them.
    fn finish_seq(
        &mut self,
        id: RequestId,
        reason: FinishReason,
        finished: &mut Vec<FinishedRequest>,
    ) {
        let seq = self.active.remove(&id).unwrap();
        // Pages the index registered from this request stay committed
        // (cached for future prompts); the rest of the reservation
        // returns to the pool.
        self.committed_pages -= seq.reserved_pages - seq.index_kept;
        let now = Instant::now();
        let fr = FinishedRequest {
            id,
            prompt_len: seq.prompt_len,
            output: seq.generated,
            reason,
            queue_s: (seq.prefill_started - seq.arrival).as_secs_f64(),
            prefill_s: (seq.first_token_at - seq.prefill_started).as_secs_f64(),
            decode_s: (now - seq.first_token_at).as_secs_f64(),
            cum_logprob: seq.cum_logprob,
            logprobs: seq.logprobs,
            parent: seq.parent,
        };
        self.timelines.observe(fr.timeline());
        finished.push(fr);
        self.batcher.release(id);
        self.cache.free_seq(id);
        self.fork_tree.remove(id);
        self.metrics.requests_finished += 1;
    }

    /// One speculative decode iteration: draft a block per live lane,
    /// score every draft position in a **single** multi-token verify
    /// pass (per-position logits from the verify artifact — the k-query
    /// lean pass over the cached context), commit the longest draft
    /// prefix that reproduces the sequential sampler's stream
    /// bit-for-bit plus one correction/bonus token, and roll the
    /// rejected draft KV back with the COW-aware
    /// [`PagedKvCache::truncate_seq`]. A request commits between 1 and
    /// `spec_k + 1` tokens per iteration; the admission budget reserves
    /// the transient draft block ([`Self::spec_overhang`]), so the eager
    /// block append can never run the cache dry. Hardware projections
    /// are recorded by plain steps only (the multi-query projection
    /// lives in `sim::spec`).
    fn decode_once_spec(&mut self, finished: &mut Vec<FinishedRequest>) -> Result<()> {
        let slots: Vec<Option<RequestId>> = self.batcher.slots().to_vec();
        let b = self.model.art.batch;
        let c = self.model.art.ctx_bucket;
        let s = self.model.art.spec_bucket;
        let k = self.config.spec_k.min(s - 1);
        // K/V planes are kv-head granular (h_kv == n_heads when ungrouped).
        let (l, h, dh) = (
            self.model.art.n_layers,
            self.model.art.n_kv_heads,
            self.model.art.head_dim,
        );
        let vocab = self.model.art.vocab;

        let views = self.gather_step_views(&slots)?;

        // Draft blocks: [pending, d_1..d_k, pad] per live lane, with the
        // draft capped by the lane's remaining budget (a pass commits at
        // most draft + 1 tokens, so drafting past the budget would only
        // score-and-roll-back wasted rows and skew acceptance metrics)
        // and, under `adaptive_spec`, by the lane's acceptance-aware
        // controller. Padded rows are scored by the artifact but never
        // accepted past the real draft.
        let mut tokens = vec![0i32; b * s];
        // True cache lengths: the rollback anchor. `views.positions` can
        // be smaller under sparse selection (compacted artifact views).
        let mut true_len = vec![0usize; b];
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); b];
        let draft_start = self.tracer.now();
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = slot else { continue };
            let seq = &self.active[id];
            true_len[bi] = self.cache.seq_len(*id).unwrap();
            tokens[bi * s] = seq.last_token;
            let remaining = seq.max_new - seq.generated.len();
            let k_adapt = if self.config.adaptive_spec {
                seq.spec_ctrl.k().min(k)
            } else {
                k
            };
            let k_lane = k_adapt.min(remaining.saturating_sub(1));
            let mut d = if k_lane > 0 {
                self.drafter.draft(&seq.tokens, k_lane)
            } else {
                Vec::new()
            };
            d.truncate(k_lane);
            let fill = d.last().copied().unwrap_or(seq.last_token);
            for i in 0..s - 1 {
                tokens[bi * s + 1 + i] = d.get(i).copied().unwrap_or(fill);
            }
            drafts[bi] = d;
        }
        let drafted: usize = drafts.iter().map(Vec::len).sum();
        self.tracer.record_since(
            Phase::SpecDraft,
            draft_start,
            Attrs { k: Some(drafted), ..Default::default() },
        );

        let t0 = Instant::now();
        let verify_start = self.tracer.now();
        let out = self
            .model
            .verify(&tokens, &self.k_buf, &self.v_buf, &views.positions)?;
        let step_us = t0.elapsed().as_secs_f64() * 1e6;
        self.metrics.decode_steps += 1;
        self.metrics.step_us.record(step_us);
        self.tracer.record_since(
            Phase::SpecVerify,
            verify_start,
            Attrs { k: Some(drafted), ..Default::default() },
        );

        let sample_start = self.tracer.now();
        let plane = l * h * dh;
        let mut nk = vec![0.0f32; plane];
        let mut nv = vec![0.0f32; plane];
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = *slot else { continue };
            let cache_len = true_len[bi];
            let draft = std::mem::take(&mut drafts[bi]);
            let rows: Vec<&[f32]> = (0..=draft.len())
                .map(|i| {
                    let base = (bi * s + i) * vocab;
                    &out.logits[base..base + vocab]
                })
                .collect();

            // Replay the sequential sampler against the per-position
            // logits: the committed prefix is bit-identical to what
            // plain decode would have produced, RNG trajectory included.
            let (verdict, remaining) = {
                let seq = self.active.get_mut(&id).unwrap();
                let v =
                    verify_chain(&rows, &draft, &seq.tokens, &seq.params, &mut seq.rng);
                // Acceptance signal for the adaptive draft-length
                // controller (the true accepted count, before the
                // remaining-budget clamp below).
                seq.spec_ctrl.observe(draft.len(), v.accepted);
                (v, seq.max_new - seq.generated.len())
            };
            let commit = verdict.committed.len().min(remaining);

            // Eagerly append the scored block (pending + this lane's
            // drafts) — the write-back a fused verify kernel performs —
            // then truncate the rejected tail. Copy-on-write protects
            // fork siblings sharing the tail page. Block row `i` was
            // rotated at compacted position `views.positions + i`; its
            // true index is `cache_len + i`, so the delta is constant
            // per lane and zero on dense steps (no-op).
            let delta = cache_len as f64 - f64::from(views.positions[bi]);
            for i in 0..=draft.len() {
                for li in 0..l {
                    for hi in 0..h {
                        let src = ((((li * b) + bi) * h + hi) * s + i) * dh;
                        let dst = (li * h + hi) * dh;
                        nk[dst..dst + dh].copy_from_slice(&out.new_k[src..src + dh]);
                        nv[dst..dst + dh].copy_from_slice(&out.new_v[src..src + dh]);
                    }
                }
                if delta > 0.0 {
                    advance_rope(&mut nk, dh, delta, self.model.art.rope_base);
                }
                if self.cache.append_token(id, &nk, &nv)? {
                    self.metrics.prefix.cow_copies += 1;
                }
            }
            self.cache.truncate_seq(id, cache_len + commit)?;
            let rolled = draft.len() + 1 - commit;
            self.metrics.spec.rolled_back += rolled;
            self.metrics.spec.verify_passes += 1;
            self.metrics.spec.drafted += draft.len();
            self.metrics.spec.accepted += commit - 1;
            self.metrics.spec.committed += commit;
            self.metrics.tokens_generated += commit;
            self.tracer.instant(
                Phase::SpecCommit,
                Attrs { seq: Some(id), k: Some(commit), ..Default::default() },
            );
            if rolled > 0 {
                self.tracer.instant(
                    Phase::Rollback,
                    Attrs { seq: Some(id), k: Some(rolled), ..Default::default() },
                );
            }

            let seq = self.active.get_mut(&id).unwrap();
            for t in &verdict.committed[..commit] {
                seq.generated.push(t.token);
                seq.tokens.push(t.token);
                seq.logprobs.push(t.logprob);
                seq.cum_logprob += f64::from(t.logprob);
            }
            seq.last_token = verdict.committed[commit - 1].token;
            seq.last_logits.clear();
            seq.last_logits.extend_from_slice(rows[commit - 1]);

            let cache_len = self.cache.seq_len(id).unwrap();
            let reason = if seq.generated.len() >= seq.max_new {
                Some(FinishReason::Length)
            } else if cache_len >= c {
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            if let Some(reason) = reason {
                self.finish_seq(id, reason, finished);
            }
        }
        self.tracer.record_since(Phase::Sample, sample_start, Attrs::default());
        Ok(())
    }

    /// Per-live-lane context lengths of the current step, plus the
    /// shared-prefix groups detected from the leading KV page runs active
    /// sequences physically share (group members are live-lane indices in
    /// slot order). Sharing is always a leading run (`insert_seq_shared`
    /// prepends the shared pages), so runs starting with the same page
    /// overlap by exactly their longest common leading run. Both the
    /// cascade-gather trigger and the hardware projection consume this;
    /// [`super::kv_cache::PagedKvCache::gather_shared`] independently
    /// re-derives the grouping from the live page lists (of which
    /// `prefix_pages` is a leading snapshot), so the two agree on any
    /// sharing the cache can express — keep them in sync if sharing ever
    /// becomes non-leading (e.g. partial-page radix edges).
    fn step_prefix_groups(&self, slots: &[Option<RequestId>]) -> (Vec<u32>, Vec<PrefixGroup>) {
        let mut lens: Vec<u32> = Vec::new();
        // (index page run, seq idx) for sequences holding indexed pages.
        let mut runs: Vec<(Vec<usize>, u32)> = Vec::new();
        for id in slots.iter().flatten() {
            let Some(len) = self.cache.seq_len(*id) else { continue };
            let seq_idx = lens.len() as u32;
            lens.push(len as u32);
            if let Some(a) = self.active.get(id) {
                if !a.prefix_pages.is_empty() {
                    runs.push((a.prefix_pages.clone(), seq_idx));
                }
            }
        }
        // BTreeMap: group order is deterministic, so projections — and
        // anything downstream of group order — reproduce across runs.
        let mut by_first: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, (run, _)) in runs.iter().enumerate() {
            by_first.entry(run[0]).or_default().push(i);
        }
        let groups: Vec<PrefixGroup> = by_first
            .into_values()
            .filter(|idxs| idxs.len() >= 2)
            .map(|idxs| {
                let head = &runs[idxs[0]].0;
                let mut common = head.len();
                for &i in &idxs[1..] {
                    let r = &runs[i].0;
                    let c = head
                        .iter()
                        .zip(r)
                        .take(common)
                        .take_while(|(a, b)| a == b)
                        .count();
                    common = c;
                }
                PrefixGroup {
                    prefix_len: (common * self.config.page_tokens) as u32,
                    members: idxs.iter().map(|&i| runs[i].1).collect(),
                }
            })
            .filter(|g| g.prefix_len > 0)
            .collect();
        (lens, groups)
    }

    /// Project this step's (ragged) attention batch onto the A100 model:
    /// what would LeanAttention vs FlashDecoding cost on real hardware —
    /// and, when sequences share cached prefixes, what does the cascade
    /// plan save by streaming each shared prefix once per group?
    fn record_projection(&mut self, lens: &[u32], groups: &[PrefixGroup]) {
        if lens.is_empty() {
            return;
        }
        let problem = DecodeProblem::ragged(
            self.model.art.n_heads,
            lens.to_vec(),
            self.model.art.head_dim,
        )
        .with_kv_heads(self.model.art.n_kv_heads);
        // Exact per-step work (tiles/flops/folds) from the same plan the
        // projection prices — the engine-side attribution totals.
        self.metrics.attrib.record_plan(&attrib::account_decode_problem(&problem));
        let la = simulate(&problem, Strategy::StreamK, &self.arch);
        let fd = simulate(
            &problem,
            Strategy::fixed_split_auto(&problem, self.arch.num_sms),
            &self.arch,
        );
        let layers = self.model.art.n_layers as f64;
        self.metrics.record_projection(
            la.latency_us * layers,
            fd.latency_us * layers,
            la.occupancy,
        );
        // Partition-balance gauges over the same stream-K plan the
        // projection priced: how level is this step's CTA schedule?
        let slots = effective_slots(Strategy::StreamK, &self.arch);
        let plan = build_plan(&problem, Strategy::StreamK, slots);
        let bal = plan_balance(&problem, &plan, &self.arch);
        self.metrics.balance.partition_imbalance = bal.imbalance;
        self.metrics.balance.wave_efficiency = bal.wave_efficiency;

        if groups.is_empty() {
            return;
        }
        let Ok(cp) = CascadeProblem::new(
            self.model.art.n_heads,
            lens.to_vec(),
            self.model.art.head_dim,
            groups.to_vec(),
        ) else {
            return;
        };
        let cp = cp.with_kv_heads(self.model.art.n_kv_heads);
        // Below one LeanTile of shared context the cascade split saves
        // nothing; align to tile boundaries so savings are never negative.
        let cp = cp.tile_aligned();
        if cp.prefix_groups.is_empty() {
            return;
        }
        let r = simulate_cascade(&cp, &self.arch);
        self.metrics.record_cascade_projection(
            r.latency_us * layers,
            (r.baseline_kv_bytes - r.kv_bytes) * layers,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_enables_prefix_cache() {
        let c = EngineConfig::default();
        assert!(c.enable_prefix_cache);
        assert!(c.project_hardware);
    }

    #[test]
    fn config_default_sampling_is_greedy_and_seeded() {
        let c = EngineConfig::default();
        assert!(c.sampling.is_greedy(), "greedy decode stays the default");
        assert_eq!(c.seed, 0);
    }

    #[test]
    fn config_default_disables_speculation() {
        let c = EngineConfig::default();
        assert_eq!(c.spec_k, 0, "speculative decoding is opt-in");
        assert_eq!(c.spec_draft, DraftKind::NGram);
        assert!(!c.adaptive_spec, "acceptance-aware k is opt-in");
    }

    #[test]
    fn config_default_streams_dense() {
        assert!(EngineConfig::default().sparse.is_none());
    }

    #[test]
    fn config_default_disables_drift_detection() {
        let c = EngineConfig::default();
        assert_eq!(c.drift_limit, 0.0, "drift detection is opt-in");
        assert!(c.drift_coefficients.is_none(), "nominal priors by default");
    }

    #[test]
    fn config_default_leaves_tracer_disabled() {
        assert_eq!(
            EngineConfig::default().trace_capacity,
            0,
            "tracing is opt-in"
        );
    }

    // Engine integration tests — including fork/cancel, best-of-n and
    // beam determinism, and the fork COW accounting — need artifacts +
    // PJRT and live in rust/tests/engine_e2e.rs.
}
