//! The decode-serving engine: continuous batching over the PJRT model
//! artifacts with a paged KV cache, greedy sampling, a radix prefix cache
//! with copy-on-write page sharing, and a per-step LeanAttention hardware
//! projection.
//!
//! One `step()` is one Orca-style iteration: admit waiting requests into
//! free slots (batch prefill), then run one decode step for every active
//! sequence. Python never runs here — both phases execute AOT-compiled
//! HLO through the PJRT CPU client.
//!
//! **Shared-prefix serving.** Prompts are probed against a
//! [`super::radix::RadixPrefixIndex`]; matched full pages are shared by
//! reference ([`PagedKvCache::insert_seq_shared`]) instead of duplicated,
//! which shrinks both the admission footprint (more concurrent sequences
//! fit) and the modeled decode bandwidth (the per-step cascade projection
//! streams each shared prefix once per group). Every admitted prompt's
//! full pages are registered back into the index so later requests can
//! share them; under memory pressure the index evicts cold pages nobody
//! else references.

use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::partition::cascade::{CascadeProblem, PrefixGroup};
use crate::partition::plan::{DecodeProblem, Strategy};
use crate::runtime::{Manifest, ModelRuntime, Runtime};
use crate::sim::cascade::simulate_cascade;
use crate::sim::{simulate, GpuArch};

use super::batcher::ContinuousBatcher;
use super::kv_cache::PagedKvCache;
use super::metrics::Metrics;
use super::radix::{PrefixMatch, RadixPrefixIndex};
use super::request::{FinishReason, FinishedRequest, Request, RequestId};

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Model name in the artifact manifest (`tiny`, `small`, ...).
    pub model: String,
    /// KV-cache pages to allocate.
    pub cache_pages: usize,
    /// Tokens per KV page.
    pub page_tokens: usize,
    /// Record per-step LeanAttention-vs-FlashDecoding GPU projections.
    pub project_hardware: bool,
    /// Share prompt-prefix KV pages across requests via the radix index.
    pub enable_prefix_cache: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "tiny".into(),
            cache_pages: 256,
            page_tokens: 16,
            project_hardware: true,
            enable_prefix_cache: true,
        }
    }
}

struct ActiveSeq {
    prompt_len: usize,
    max_new: usize,
    last_token: i32,
    generated: Vec<i32>,
    arrival: Instant,
    prefill_started: Instant,
    first_token_at: Instant,
    /// Fresh KV pages reserved for this request's full budget at
    /// admission (cached prefix pages are excluded — the index holds
    /// those).
    reserved_pages: usize,
    /// Of this request's pages, how many the prefix index newly
    /// registered (they outlive the request, so its release returns
    /// `reserved_pages - index_kept` to the committed-pages pool).
    index_kept: usize,
    /// This sequence's leading full KV pages (shared prefix pages it
    /// references + its own prompt pages). Sequences whose runs share a
    /// leading segment physically share those pages and form a cascade
    /// prefix group — including the request that populated the index,
    /// not just later matchers. Every listed page is in the sequence's
    /// own page list, so it stays referenced while the request is active.
    prefix_pages: Vec<usize>,
}

/// A single-replica serving engine.
pub struct Engine {
    pub config: EngineConfig,
    model: ModelRuntime,
    cache: PagedKvCache,
    batcher: ContinuousBatcher,
    active: HashMap<RequestId, ActiveSeq>,
    prefix_index: RadixPrefixIndex,
    pub metrics: Metrics,
    arch: GpuArch,
    next_id: RequestId,
    /// Pages committed to being (or becoming) allocated: the prefix
    /// index's pages plus every active request's fresh-page budget.
    /// Admission keeps `committed + need <= total`, so same-wave
    /// admissions and later decode appends can never run the cache dry
    /// mid-flight.
    committed_pages: usize,
    // reusable gather buffers (hot path: no per-step allocation)
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
}

impl Engine {
    /// Load artifacts and bring up the engine.
    pub fn new(runtime: &Rc<Runtime>, manifest: &Manifest, config: EngineConfig) -> Result<Engine> {
        let model = ModelRuntime::load(runtime, manifest, &config.model)
            .with_context(|| format!("load model {:?}", config.model))?;
        let art = &model.art;
        let cache = PagedKvCache::new(
            art.n_layers,
            art.n_heads,
            art.head_dim,
            config.page_tokens,
            config.cache_pages,
        );
        let batcher = ContinuousBatcher::new(art.batch);
        let prefix_index = RadixPrefixIndex::new(config.page_tokens);
        let cache_elems = model.cache_elems();
        Ok(Engine {
            config,
            model,
            cache,
            batcher,
            active: HashMap::new(),
            prefix_index,
            metrics: Metrics::default(),
            arch: GpuArch::a100(),
            next_id: 1,
            committed_pages: 0,
            k_buf: vec![0.0; cache_elems],
            v_buf: vec![0.0; cache_elems],
        })
    }

    pub fn model_name(&self) -> &str {
        &self.model.art.name
    }

    pub fn batch_size(&self) -> usize {
        self.model.art.batch
    }

    pub fn ctx_bucket(&self) -> usize {
        self.model.art.ctx_bucket
    }

    pub fn prefill_bucket(&self) -> usize {
        self.model.art.prefill_bucket
    }

    pub fn waiting(&self) -> usize {
        self.batcher.waiting_len()
    }

    pub fn active(&self) -> usize {
        self.batcher.active_len()
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.is_idle()
    }

    /// Pages currently pinned by the radix prefix index.
    pub fn prefix_index_pages(&self) -> usize {
        self.prefix_index.num_pages()
    }

    /// Submit a request; returns its id. The prompt must fit the prefill
    /// bucket and the vocab, and the generation budget must be at least
    /// one token (prefill always produces one, so `max_new_tokens = 0`
    /// has no meaningful contract and is rejected).
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<RequestId> {
        ensure!(max_new_tokens >= 1, "max_new_tokens must be >= 1");
        ensure!(
            !prompt.is_empty() && prompt.len() <= self.model.art.prefill_bucket,
            "prompt length {} outside [1, {}]",
            prompt.len(),
            self.model.art.prefill_bucket
        );
        ensure!(
            prompt.iter().all(|&t| t >= 0 && (t as usize) < self.model.art.vocab),
            "token outside vocab"
        );
        // A request whose full budget can never fit would deadlock the
        // FCFS queue — reject it up front.
        let budget = (prompt.len() + max_new_tokens).min(self.model.art.ctx_bucket);
        ensure!(
            self.cache.pages_for(budget) <= self.cache.total_pages(),
            "request budget of {budget} tokens exceeds total KV capacity"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.batcher.enqueue(Request::new(id, prompt, max_new_tokens));
        Ok(id)
    }

    /// One engine iteration: admissions (+ batched prefill) and one decode
    /// step. Returns requests that finished during this iteration.
    pub fn step(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut finished = Vec::new();
        self.admit_and_prefill(&mut finished)?;
        self.decode_once(&mut finished)?;
        Ok(finished)
    }

    /// Drive until every submitted request completes.
    pub fn run_until_idle(&mut self) -> Result<Vec<FinishedRequest>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step()?);
        }
        Ok(all)
    }

    fn admit_and_prefill(&mut self, finished: &mut Vec<FinishedRequest>) -> Result<()> {
        let ctx_cap = self.model.art.ctx_bucket;
        let budget = |r: &Request| (r.prompt.len() + r.max_new_tokens).min(ctx_cap);

        // Under memory pressure, evict cold prefix-index pages nobody
        // else references so the queue head can fit. The head's match is
        // kept (eviction spares those pages) and handed to the admission
        // gate below, saving a redundant trie walk per congested step.
        let mut head_match: Option<PrefixMatch> = None;
        if self.config.enable_prefix_cache
            && self.batcher.free_slots() > 0
            && !self.prefix_index.is_empty()
        {
            if let Some(front) = self.batcher.peek_waiting() {
                let m = self.prefix_index.peek(&front.prompt);
                self.metrics.prefix.lookups += 1;
                let need = self
                    .cache
                    .pages_for(budget(front))
                    .saturating_sub(m.pages.len());
                let available = self
                    .cache
                    .total_pages()
                    .saturating_sub(self.committed_pages);
                if need > available {
                    let cache = &self.cache;
                    // Spare the pages the head request is about to share.
                    let evicted = self.prefix_index.evict_lru(need - available, |p| {
                        cache.page_ref(p) == 1 && !m.pages.contains(&p)
                    });
                    for &p in &evicted {
                        self.cache.release_page(p)?;
                    }
                    self.committed_pages -= evicted.len();
                    self.metrics.prefix.evicted_pages += evicted.len();
                }
                head_match = Some(m);
            }
        }

        // Admit up to the free slots, gated by KV page availability for
        // the prompt plus the *whole* generation budget (minus pages a
        // cached prefix already provides), reserving as we go. The budget
        // caps at the ctx bucket (generation stops there with ContextFull
        // regardless).
        let cache = &self.cache;
        let prefix_index = &self.prefix_index;
        let use_prefix = self.config.enable_prefix_cache;
        let mut committed = self.committed_pages;
        let total = cache.total_pages();
        let mut needs: Vec<usize> = Vec::new();
        // Gate-time probes of queued/rejected requests count too — the
        // hit rate is per actual index probe, not per admitted request.
        let mut gate_probes = 0usize;
        let admitted = self.batcher.admit(|r| {
            let m = if use_prefix {
                // First gate call is the same head the eviction pass
                // probed; its match is unchanged (eviction spared it).
                head_match.take().unwrap_or_else(|| {
                    gate_probes += 1;
                    prefix_index.peek(&r.prompt)
                })
            } else {
                PrefixMatch::default()
            };
            let need = cache.pages_for(budget(r)).saturating_sub(m.pages.len());
            if committed + need <= total {
                committed += need;
                needs.push(need);
                true
            } else {
                false
            }
        });
        self.committed_pages = committed;
        self.metrics.prefix.lookups += gate_probes;
        if admitted.is_empty() {
            return Ok(());
        }

        let b = self.model.art.batch;
        let p = self.model.art.prefill_bucket;
        let mut tokens = vec![0i32; b * p];
        let mut lengths = vec![1i32; b]; // dummy lanes prefill 1 token
        for (slot, r) in &admitted {
            tokens[slot * p..slot * p + r.prompt.len()].copy_from_slice(&r.prompt);
            lengths[*slot] = r.prompt.len() as i32;
        }

        let t0 = Instant::now();
        let out = self.model.prefill(&tokens, &lengths)?;
        self.metrics.prefill_calls += 1;
        self.metrics
            .prefill_us
            .push(t0.elapsed().as_secs_f64() * 1e6);

        let (l, h, dh) = (
            self.model.art.n_layers,
            self.model.art.n_heads,
            self.model.art.head_dim,
        );
        let vocab = self.model.art.vocab;
        for ((slot, r), need) in admitted.into_iter().zip(needs) {
            let len = r.prompt.len();
            // Re-probe the index now: an earlier request in this same
            // admission wave may have just registered the shared prefix,
            // so a cold burst of identical prompts still deduplicates
            // everything after the first. (Admission reserved pages using
            // the pre-wave probe — a larger match here only means fewer
            // fresh pages than reserved, which the finish-time release
            // balances.) This probe is the one that commits to sharing,
            // so it goes through `lookup` to refresh the LRU stamps of
            // the matched chain — `peek` stays reserved for
            // admission-control probes, which must not perturb eviction
            // order. Without the bump here, eviction degrades to
            // insertion order and can evict a hot system prompt.
            let m = if use_prefix {
                self.metrics.prefix.lookups += 1;
                self.prefix_index.lookup(&r.prompt)
            } else {
                PrefixMatch::default()
            };
            // Extract this lane's K/V rows *after* the cached prefix as
            // [l, h, suffix, dh] — the prefix pages are shared, so only
            // the suffix is written into fresh pages.
            let skip = m.tokens;
            let suffix = len - skip;
            let mut k = vec![0.0f32; l * h * suffix * dh];
            let mut v = vec![0.0f32; l * h * suffix * dh];
            for li in 0..l {
                for hi in 0..h {
                    for t in 0..suffix {
                        let src = ((((li * b) + slot) * h + hi) * p + skip + t) * dh;
                        let dst = ((li * h + hi) * suffix + t) * dh;
                        k[dst..dst + dh].copy_from_slice(&out.k[src..src + dh]);
                        v[dst..dst + dh].copy_from_slice(&out.v[src..src + dh]);
                    }
                }
            }
            if skip > 0 {
                self.cache.insert_seq_shared(r.id, &m.pages, &k, &v, suffix)?;
            } else {
                self.cache.insert_seq(r.id, &k, &v, len)?;
            }

            // Account the hit and register this prompt's full pages so
            // later requests can share them.
            let mut index_kept = 0;
            let mut prefix_run = Vec::new();
            if use_prefix {
                if skip > 0 {
                    self.metrics.prefix.hits += 1;
                    self.metrics.prefix.tokens_matched += skip;
                    self.metrics.prefix.pages_shared += m.pages.len();
                    self.metrics.prefix.kv_bytes_deduped +=
                        (m.pages.len() * self.cache.page_bytes()) as u64;
                }
                let pages = self.cache.seq_pages(r.id).unwrap().to_vec();
                let fresh = self.prefix_index.insert(&r.prompt, &pages);
                for &pg in &fresh {
                    self.cache.retain_page(pg)?;
                }
                index_kept = fresh.len();
                // This sequence's leading full pages — shared prefix pages
                // plus the pages it just registered. Every page here is in
                // its own page list (reference held while active), so the
                // cascade grouping below can never see a freed-and-reused
                // page id; and the prefix *owner* participates in groups,
                // not just later matchers.
                let full = (len / self.config.page_tokens).min(pages.len());
                prefix_run = pages[..full].to_vec();
            }

            // First generated token from the prefill logits.
            let logits = &out.logits[slot * vocab..(slot + 1) * vocab];
            let first = argmax(logits);
            let now = Instant::now();
            self.metrics.tokens_generated += 1;

            // A one-token budget is already satisfied by the prefill
            // logits: finish here instead of letting the decode loop push
            // a second token past the budget (`submit` rejects budget 0).
            if r.max_new_tokens <= 1 {
                self.committed_pages -= need - index_kept;
                finished.push(FinishedRequest {
                    id: r.id,
                    prompt_len: len,
                    output: vec![first],
                    reason: FinishReason::Length,
                    queue_s: (t0 - r.arrival).as_secs_f64(),
                    prefill_s: (now - t0).as_secs_f64(),
                    decode_s: 0.0,
                });
                self.batcher.release(r.id);
                self.cache.free_seq(r.id);
                self.metrics.requests_finished += 1;
                continue;
            }

            self.active.insert(
                r.id,
                ActiveSeq {
                    prompt_len: len,
                    max_new: r.max_new_tokens,
                    last_token: first,
                    generated: vec![first],
                    arrival: r.arrival,
                    prefill_started: t0,
                    first_token_at: now,
                    reserved_pages: need,
                    index_kept,
                    prefix_pages: prefix_run,
                },
            );
        }
        Ok(())
    }

    fn decode_once(&mut self, finished: &mut Vec<FinishedRequest>) -> Result<()> {
        if self.batcher.active_len() == 0 {
            return Ok(());
        }
        let slots: Vec<Option<RequestId>> = self.batcher.slots().to_vec();
        let b = self.model.art.batch;
        let c = self.model.art.ctx_bucket;
        let (l, h, dh) = (
            self.model.art.n_layers,
            self.model.art.n_heads,
            self.model.art.head_dim,
        );
        let vocab = self.model.art.vocab;

        // Detect physically-shared leading page runs once per step: both
        // the gather below and the hardware projection consume them.
        let detect = self.config.enable_prefix_cache || self.config.project_hardware;
        let (lens, groups) = if detect {
            self.step_prefix_groups(&slots)
        } else {
            (Vec::new(), Vec::new())
        };

        // Gather paged caches into the contiguous decode views. Steps
        // whose lanes share a prefix run take the cascade (Strategy::
        // Cascade) gather: each shared run is materialized once and
        // scattered into its member lanes, and the measured dedup is
        // recorded. Solo steps keep the allocation-free flat gather.
        //
        // The monolithic decode HLO still consumes dense per-lane views,
        // so on this CPU path the scatter re-expands the runs (segment
        // allocation + one extra copy per shared run vs the flat gather);
        // the SharedSegment views are the shape a kernel-level cascade
        // attention consumes directly, at which point compose_dense
        // disappears. gather_shared re-derives the same leading-run
        // grouping as step_prefix_groups from the live page lists (the
        // physical ground truth); kv_cache_props pins the two paths'
        // views bit-identical either way.
        if groups.is_empty() {
            self.cache.gather(&slots, c, &mut self.k_buf, &mut self.v_buf)?;
        } else {
            let sg = self.cache.gather_shared(&slots)?;
            sg.compose_dense(c, &mut self.k_buf, &mut self.v_buf)?;
            self.metrics.cascade_gather_steps += 1;
            self.metrics.gather_bytes_flat += sg.flat_bytes as u64;
            self.metrics.gather_bytes_shared += sg.shared_bytes as u64;
        }

        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        for (bi, slot) in slots.iter().enumerate() {
            if let Some(id) = slot {
                let seq = &self.active[id];
                tokens[bi] = seq.last_token;
                positions[bi] = self.cache.seq_len(*id).unwrap() as i32;
            }
        }

        let t0 = Instant::now();
        let out = self
            .model
            .decode(&tokens, &self.k_buf, &self.v_buf, &positions)?;
        let step_us = t0.elapsed().as_secs_f64() * 1e6;
        self.metrics.decode_steps += 1;
        self.metrics.step_us.push(step_us);

        if self.config.project_hardware {
            self.record_projection(&lens, &groups);
        }

        // Per-lane: append fresh KV, sample, check termination.
        let plane = l * h * dh;
        let mut nk = vec![0.0f32; plane];
        let mut nv = vec![0.0f32; plane];
        for (bi, slot) in slots.iter().enumerate() {
            let Some(id) = *slot else { continue };
            for li in 0..l {
                for hi in 0..h {
                    let src = (((li * b) + bi) * h + hi) * dh;
                    let dst = (li * h + hi) * dh;
                    nk[dst..dst + dh].copy_from_slice(&out.new_k[src..src + dh]);
                    nv[dst..dst + dh].copy_from_slice(&out.new_v[src..src + dh]);
                }
            }
            if self.cache.append_token(id, &nk, &nv)? {
                self.metrics.prefix.cow_copies += 1;
            }

            let seq = self.active.get_mut(&id).unwrap();
            let logits = &out.logits[bi * vocab..(bi + 1) * vocab];
            let next = argmax(logits);
            seq.generated.push(next);
            seq.last_token = next;
            self.metrics.tokens_generated += 1;

            let cache_len = self.cache.seq_len(id).unwrap();
            let reason = if seq.generated.len() >= seq.max_new {
                Some(FinishReason::Length)
            } else if cache_len >= c {
                Some(FinishReason::ContextFull)
            } else {
                None
            };
            if let Some(reason) = reason {
                let seq = self.active.remove(&id).unwrap();
                // Pages the index registered from this request stay
                // committed (cached for future prompts); the rest of the
                // reservation returns to the pool.
                self.committed_pages -= seq.reserved_pages - seq.index_kept;
                let now = Instant::now();
                finished.push(FinishedRequest {
                    id,
                    prompt_len: seq.prompt_len,
                    output: seq.generated,
                    reason,
                    queue_s: (seq.prefill_started - seq.arrival).as_secs_f64(),
                    prefill_s: (seq.first_token_at - seq.prefill_started)
                        .as_secs_f64(),
                    decode_s: (now - seq.first_token_at).as_secs_f64(),
                });
                self.batcher.release(id);
                self.cache.free_seq(id);
                self.metrics.requests_finished += 1;
            }
        }
        Ok(())
    }

    /// Per-live-lane context lengths of the current step, plus the
    /// shared-prefix groups detected from the leading KV page runs active
    /// sequences physically share (group members are live-lane indices in
    /// slot order). Sharing is always a leading run (`insert_seq_shared`
    /// prepends the shared pages), so runs starting with the same page
    /// overlap by exactly their longest common leading run. Both the
    /// cascade-gather trigger and the hardware projection consume this;
    /// [`super::kv_cache::PagedKvCache::gather_shared`] independently
    /// re-derives the grouping from the live page lists (of which
    /// `prefix_pages` is a leading snapshot), so the two agree on any
    /// sharing the cache can express — keep them in sync if sharing ever
    /// becomes non-leading (e.g. partial-page radix edges).
    fn step_prefix_groups(&self, slots: &[Option<RequestId>]) -> (Vec<u32>, Vec<PrefixGroup>) {
        let mut lens: Vec<u32> = Vec::new();
        // (index page run, seq idx) for sequences holding indexed pages.
        let mut runs: Vec<(Vec<usize>, u32)> = Vec::new();
        for id in slots.iter().flatten() {
            let Some(len) = self.cache.seq_len(*id) else { continue };
            let seq_idx = lens.len() as u32;
            lens.push(len as u32);
            if let Some(a) = self.active.get(id) {
                if !a.prefix_pages.is_empty() {
                    runs.push((a.prefix_pages.clone(), seq_idx));
                }
            }
        }
        let mut by_first: HashMap<usize, Vec<usize>> = HashMap::new();
        for (i, (run, _)) in runs.iter().enumerate() {
            by_first.entry(run[0]).or_default().push(i);
        }
        let groups: Vec<PrefixGroup> = by_first
            .into_values()
            .filter(|idxs| idxs.len() >= 2)
            .map(|idxs| {
                let head = &runs[idxs[0]].0;
                let mut common = head.len();
                for &i in &idxs[1..] {
                    let r = &runs[i].0;
                    let c = head
                        .iter()
                        .zip(r)
                        .take(common)
                        .take_while(|(a, b)| a == b)
                        .count();
                    common = c;
                }
                PrefixGroup {
                    prefix_len: (common * self.config.page_tokens) as u32,
                    members: idxs.iter().map(|&i| runs[i].1).collect(),
                }
            })
            .filter(|g| g.prefix_len > 0)
            .collect();
        (lens, groups)
    }

    /// Project this step's (ragged) attention batch onto the A100 model:
    /// what would LeanAttention vs FlashDecoding cost on real hardware —
    /// and, when sequences share cached prefixes, what does the cascade
    /// plan save by streaming each shared prefix once per group?
    fn record_projection(&mut self, lens: &[u32], groups: &[PrefixGroup]) {
        if lens.is_empty() {
            return;
        }
        let problem = DecodeProblem::ragged(
            self.model.art.n_heads,
            lens.to_vec(),
            self.model.art.head_dim,
        );
        let la = simulate(&problem, Strategy::StreamK, &self.arch);
        let fd = simulate(
            &problem,
            Strategy::fixed_split_auto(&problem, self.arch.num_sms),
            &self.arch,
        );
        let layers = self.model.art.n_layers as f64;
        self.metrics.projected_lean_us.push(la.latency_us * layers);
        self.metrics.projected_fd_us.push(fd.latency_us * layers);
        self.metrics.projected_occupancy.push(la.occupancy);

        if groups.is_empty() {
            return;
        }
        let Ok(cp) = CascadeProblem::new(
            self.model.art.n_heads,
            lens.to_vec(),
            self.model.art.head_dim,
            groups.to_vec(),
        ) else {
            return;
        };
        // Below one LeanTile of shared context the cascade split saves
        // nothing; align to tile boundaries so savings are never negative.
        let cp = cp.tile_aligned();
        if cp.prefix_groups.is_empty() {
            return;
        }
        let r = simulate_cascade(&cp, &self.arch);
        self.metrics.projected_cascade_us.push(r.latency_us * layers);
        self.metrics.cascade_kv_bytes_saved +=
            (r.baseline_kv_bytes - r.kv_bytes) * layers;
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -5.0]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn config_default_enables_prefix_cache() {
        let c = EngineConfig::default();
        assert!(c.enable_prefix_cache);
        assert!(c.project_hardware);
    }

    // Engine integration tests (need artifacts + PJRT) live in
    // rust/tests/engine_e2e.rs.
}
