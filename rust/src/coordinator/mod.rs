//! L3 coordinator: a decode-phase serving engine with LeanAttention as a
//! first-class scheduling policy.
//!
//! * [`request`] — request lifecycle types.
//! * [`kv_cache`] — paged KV cache (block tables, page reuse).
//! * [`batcher`] — Orca-style continuous batching (iteration-level
//!   admission into fixed engine slots).
//! * [`engine`] — the serving loop: prefill admissions → decode steps via
//!   the PJRT model artifact → sampling → cache append; every step also
//!   derives the stream-K attention plan for the current (ragged) batch
//!   and records the projected GPU latency/occupancy against the
//!   FlashDecoding baseline.
//! * [`radix`] — radix prefix index: token prefixes → shared KV page
//!   runs (the serving half of cascade/shared-prefix decoding).
//! * [`router`] — multi-engine front door (least-loaded dispatch).
//! * [`metrics`] — latency/throughput accounting, including prefix-cache
//!   hit rates and deduplicated KV bytes.
//! * [`pool`] — std-thread fork-join pool (tokio is not in the offline
//!   crate cache; the event loop is plain Rust).

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod pool;
pub mod radix;
pub mod request;
pub mod router;

pub use engine::{Engine, EngineConfig};
pub use kv_cache::PagedKvCache;
pub use metrics::{Metrics, PrefixCacheStats};
pub use radix::{PrefixMatch, RadixPrefixIndex};
pub use request::{FinishedRequest, Request, RequestId};
pub use router::Router;
