//! L3 coordinator: a decode-phase serving engine with LeanAttention as a
//! first-class scheduling policy.
//!
//! * [`request`] — request lifecycle types.
//! * [`kv_cache`] — paged KV cache (block tables, page reuse).
//! * [`batcher`] — Orca-style continuous batching (iteration-level
//!   admission into fixed engine slots).
//! * [`engine`] — the serving loop: prefill admissions → decode steps via
//!   the PJRT model artifact → sampling → cache append; every step also
//!   derives the stream-K attention plan for the current (ragged) batch
//!   and records the projected GPU latency/occupancy against the
//!   FlashDecoding baseline.
//! * [`router`] — multi-engine front door (least-loaded dispatch).
//! * [`metrics`] — latency/throughput accounting.
//! * [`pool`] — std-thread fork-join pool (tokio is not in the offline
//!   crate cache; the event loop is plain Rust).

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod router;

pub use engine::{Engine, EngineConfig};
pub use kv_cache::PagedKvCache;
pub use request::{FinishedRequest, Request, RequestId};
pub use router::Router;
