//! L3 coordinator: a decode-phase serving engine with LeanAttention as a
//! first-class scheduling policy.
//!
//! * [`request`] — request lifecycle types.
//! * [`kv_cache`] — paged KV cache (block tables, page reuse).
//! * [`batcher`] — Orca-style continuous batching (iteration-level
//!   admission into fixed engine slots).
//! * [`engine`] — the serving loop: prefill admissions → decode steps via
//!   the PJRT model artifact → the logits-sampling pipeline → cache
//!   append; plus the zero-copy `fork`/`cancel` lifecycle parallel
//!   sampling (best-of-n, beam search) drives. Every step also derives
//!   the stream-K attention plan for the current (ragged) batch and
//!   records the projected GPU latency/occupancy against the
//!   FlashDecoding baseline.
//! * [`radix`] — radix prefix index: token prefixes → shared KV page
//!   runs (the serving half of cascade/shared-prefix decoding).
//! * [`router`] — multi-engine front door (prefix-affinity dispatch:
//!   requests steer to the replica holding the longest cached prefix,
//!   round-robin on ties, with a load valve that drops affinity when the
//!   warm replica's queue skews past the cap).
//! * [`metrics`] — latency/throughput accounting, including prefix-cache
//!   hit rates and deduplicated KV bytes; phase timings live in
//!   log-bucketed histograms ([`crate::obs::LogHistogram`]) and every
//!   documented counter exports through one
//!   [`crate::obs::MetricsSnapshot`].
//! * [`pool`] — std-thread fork-join pool (tokio is not in the offline
//!   crate cache; the event loop is plain Rust).

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod pool;
pub mod radix;
pub mod request;
pub mod router;

pub use engine::{AuditPlan, Engine, EngineConfig};
pub use kv_cache::PagedKvCache;
pub use metrics::{
    GatherKind, Metrics, PrefixCacheStats, SamplingStats, SparseStats,
    DOCUMENTED_METRICS,
};
pub use radix::{PrefixMatch, RadixPrefixIndex};
pub use request::{FinishReason, FinishedRequest, Request, RequestId};
pub use router::Router;
