//! Radix prefix index: token prefixes → KV page runs.
//!
//! The serving-side half of cascade decoding (the SGLang/vLLM "radix
//! cache" idea): prompts that share a prefix — system prompts, few-shot
//! templates, parallel sampling — should share the KV pages holding that
//! prefix instead of re-prefilling and re-storing it per request.
//!
//! Sharing is only sound at **page granularity** (a page is the unit the
//! [`super::kv_cache::PagedKvCache`] refcounts), so the tree is a radix
//! trie whose every edge is one *full page* of tokens: a node compares an
//! entire `page_tokens`-sized chunk at once and owns the physical page
//! holding that chunk's K/V for all layers and heads. A prompt's partial
//! trailing page is never indexed — it may still grow in place.
//!
//! The index itself holds one cache reference per indexed page (taken by
//! the caller via `retain_page` on the pages [`RadixPrefixIndex::insert`]
//! reports as new). Sequences that match a prefix take further references;
//! eviction under memory pressure releases only pages whose sole remaining
//! reference is the index — never pages an active sequence still reads.

use crate::obs::cache_stats::RadixStats;

/// Result of a prefix lookup: the longest indexed page run covering the
/// head of the token sequence.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PrefixMatch {
    /// Physical pages of the matched prefix, in order.
    pub pages: Vec<usize>,
    /// Tokens covered: `pages.len() * page_tokens`.
    pub tokens: usize,
}

struct Node {
    /// Exactly `page_tokens` tokens — the edge label.
    chunk: Vec<i32>,
    /// Physical page holding this chunk's K/V.
    page: usize,
    /// LRU stamp (index-wide logical clock).
    last_used: u64,
    children: Vec<Node>,
}

/// Page-granular radix tree over token prefixes.
pub struct RadixPrefixIndex {
    page_tokens: usize,
    roots: Vec<Node>,
    clock: u64,
    num_pages: usize,
    /// Lookups by matched depth in pages (`[0]` counts misses) — the
    /// hit-depth half of [`RadixPrefixIndex::stats`], maintained
    /// incrementally because matched depth is not recoverable from the
    /// tree shape.
    hit_depth: Vec<u64>,
}

impl RadixPrefixIndex {
    pub fn new(page_tokens: usize) -> RadixPrefixIndex {
        assert!(page_tokens >= 1);
        RadixPrefixIndex {
            page_tokens,
            roots: Vec::new(),
            clock: 0,
            num_pages: 0,
            hit_depth: vec![0],
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Pages currently indexed.
    pub fn num_pages(&self) -> usize {
        self.num_pages
    }

    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }

    /// Longest indexed prefix of `tokens`, bumping LRU stamps along the
    /// matched path (a hit keeps the whole prefix chain hot).
    pub fn lookup(&mut self, tokens: &[i32]) -> PrefixMatch {
        self.clock += 1;
        let clock = self.clock;
        let mut m = PrefixMatch::default();
        let mut nodes = &mut self.roots;
        for chunk in tokens.chunks_exact(self.page_tokens) {
            let Some(pos) = nodes.iter().position(|n| n.chunk == chunk) else {
                break;
            };
            let node = &mut nodes[pos];
            node.last_used = clock;
            m.pages.push(node.page);
            nodes = &mut node.children;
        }
        m.tokens = m.pages.len() * self.page_tokens;
        let depth = m.pages.len();
        if self.hit_depth.len() <= depth {
            self.hit_depth.resize(depth + 1, 0);
        }
        self.hit_depth[depth] += 1;
        m
    }

    /// Longest indexed prefix of `tokens` without touching LRU state
    /// (admission-control probes must not alter eviction order).
    pub fn peek(&self, tokens: &[i32]) -> PrefixMatch {
        let mut m = PrefixMatch::default();
        let mut nodes = &self.roots;
        for chunk in tokens.chunks_exact(self.page_tokens) {
            let Some(node) = nodes.iter().find(|n| n.chunk == chunk) else {
                break;
            };
            m.pages.push(node.page);
            nodes = &node.children;
        }
        m.tokens = m.pages.len() * self.page_tokens;
        m
    }

    /// Index the full-page chunks of `tokens`, where `pages[i]` is the
    /// physical page holding chunk `i` (a sequence's in-order page list
    /// works directly). Chunks already present keep their existing page;
    /// the trailing partial chunk, if any, is ignored. Returns the pages
    /// newly referenced by the index — the caller must take one cache
    /// reference on each (and only each) of these.
    pub fn insert(&mut self, tokens: &[i32], pages: &[usize]) -> Vec<usize> {
        self.clock += 1;
        let clock = self.clock;
        let mut fresh = Vec::new();
        let mut nodes = &mut self.roots;
        for (ci, chunk) in tokens.chunks_exact(self.page_tokens).enumerate() {
            if ci >= pages.len() {
                break;
            }
            let pos = match nodes.iter().position(|n| n.chunk == chunk) {
                Some(p) => p,
                None => {
                    nodes.push(Node {
                        chunk: chunk.to_vec(),
                        page: pages[ci],
                        last_used: clock,
                        children: Vec::new(),
                    });
                    fresh.push(pages[ci]);
                    self.num_pages += 1;
                    nodes.len() - 1
                }
            };
            let node = &mut nodes[pos];
            node.last_used = clock;
            nodes = &mut node.children;
        }
        fresh
    }

    /// Evict up to `max_pages` least-recently-used **leaf** pages for
    /// which `evictable` holds (the caller checks the cache refcount is 1,
    /// i.e. the index holds the only reference). Returns the evicted
    /// pages; the caller must release one cache reference per page.
    /// Leaf-only eviction keeps every surviving prefix chain contiguous.
    pub fn evict_lru(
        &mut self,
        max_pages: usize,
        evictable: impl Fn(usize) -> bool,
    ) -> Vec<usize> {
        let mut out = Vec::new();
        while out.len() < max_pages {
            let mut best: Option<(u64, usize)> = None;
            Self::coldest_leaf(&self.roots, &evictable, &mut best);
            let Some((_, page)) = best else { break };
            let removed = Self::remove_leaf(&mut self.roots, page);
            debug_assert!(removed);
            self.num_pages -= 1;
            out.push(page);
        }
        out
    }

    fn coldest_leaf(
        nodes: &[Node],
        evictable: &impl Fn(usize) -> bool,
        best: &mut Option<(u64, usize)>,
    ) {
        for n in nodes {
            if n.children.is_empty() {
                if evictable(n.page)
                    && best.map_or(true, |(t, _)| n.last_used < t)
                {
                    *best = Some((n.last_used, n.page));
                }
            } else {
                Self::coldest_leaf(&n.children, evictable, best);
            }
        }
    }

    /// Every indexed page, in tree-walk order — the audit's ground
    /// truth for "the index holds one cache reference per page".
    pub fn pages(&self) -> Vec<usize> {
        fn walk(nodes: &[Node], out: &mut Vec<usize>) {
            for n in nodes {
                out.push(n.page);
                walk(&n.children, out);
            }
        }
        let mut out = Vec::with_capacity(self.num_pages);
        walk(&self.roots, &mut out);
        out
    }

    /// Tree-shape statistics (depth and branching histograms from a full
    /// walk) plus the incrementally-maintained lookup hit-depth counts.
    pub fn stats(&self) -> RadixStats {
        fn walk(nodes: &[Node], depth: usize, s: &mut RadixStats) {
            if nodes.is_empty() {
                return;
            }
            if s.depth_hist.len() <= depth {
                s.depth_hist.resize(depth + 1, 0);
            }
            s.max_depth = s.max_depth.max(depth + 1);
            for n in nodes {
                s.depth_hist[depth] += 1;
                let kids = n.children.len();
                if s.branching_hist.len() <= kids {
                    s.branching_hist.resize(kids + 1, 0);
                }
                s.branching_hist[kids] += 1;
                walk(&n.children, depth + 1, s);
            }
        }
        let mut s = RadixStats {
            pages: self.num_pages,
            hit_depth_hist: self.hit_depth.clone(),
            lookups: self.hit_depth.iter().sum(),
            ..RadixStats::default()
        };
        walk(&self.roots, 0, &mut s);
        s
    }

    fn remove_leaf(nodes: &mut Vec<Node>, page: usize) -> bool {
        if let Some(pos) = nodes
            .iter()
            .position(|n| n.children.is_empty() && n.page == page)
        {
            nodes.remove(pos);
            return true;
        }
        for n in nodes.iter_mut() {
            if Self::remove_leaf(&mut n.children, page) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(xs: &[i32]) -> Vec<i32> {
        xs.to_vec()
    }

    #[test]
    fn empty_index_matches_nothing() {
        let mut idx = RadixPrefixIndex::new(4);
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 5]), PrefixMatch::default());
        assert!(idx.is_empty());
        assert_eq!(idx.num_pages(), 0);
    }

    #[test]
    fn insert_then_lookup_full_pages_only() {
        let mut idx = RadixPrefixIndex::new(4);
        // 10 tokens over pages [7, 8, 9]: only 2 full chunks are indexable.
        let prompt = toks(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        let fresh = idx.insert(&prompt, &[7, 8, 9]);
        assert_eq!(fresh, vec![7, 8]);
        assert_eq!(idx.num_pages(), 2);

        let m = idx.lookup(&prompt);
        assert_eq!(m.pages, vec![7, 8]);
        assert_eq!(m.tokens, 8);

        // A shorter probe sharing one page matches one chunk.
        let m1 = idx.peek(&[1, 2, 3, 4, 99, 98, 97, 96]);
        assert_eq!(m1.pages, vec![7]);
        assert_eq!(m1.tokens, 4);

        // A diverging probe matches nothing.
        assert_eq!(idx.peek(&[9, 9, 9, 9]), PrefixMatch::default());
    }

    #[test]
    fn reinsert_is_idempotent() {
        let mut idx = RadixPrefixIndex::new(2);
        let prompt = toks(&[1, 2, 3, 4]);
        assert_eq!(idx.insert(&prompt, &[10, 11]), vec![10, 11]);
        // Same tokens from another sequence with different pages: the
        // existing pages win, nothing new is referenced.
        assert_eq!(idx.insert(&prompt, &[20, 21]), Vec::<usize>::new());
        assert_eq!(idx.num_pages(), 2);
        assert_eq!(idx.lookup(&prompt).pages, vec![10, 11]);
    }

    #[test]
    fn divergent_suffixes_share_the_common_prefix() {
        let mut idx = RadixPrefixIndex::new(2);
        idx.insert(&[5, 6, 1, 1], &[0, 1]);
        let fresh = idx.insert(&[5, 6, 2, 2], &[0, 2]);
        assert_eq!(fresh, vec![2]); // page 0 shared via the tree
        assert_eq!(idx.num_pages(), 3);
        assert_eq!(idx.lookup(&[5, 6, 1, 1]).pages, vec![0, 1]);
        assert_eq!(idx.lookup(&[5, 6, 2, 2]).pages, vec![0, 2]);
    }

    #[test]
    fn evicts_lru_leaves_first_and_respects_gate() {
        let mut idx = RadixPrefixIndex::new(2);
        idx.insert(&[1, 1, 2, 2], &[0, 1]); // chain 0 -> 1
        idx.insert(&[3, 3], &[2]); // separate root
        // Touch the first chain so page 2 is coldest.
        idx.lookup(&[1, 1, 2, 2]);

        // Gate refuses page 2: eviction takes the coldest *allowed* leaf
        // (page 1, the deeper chain's leaf) instead; page 0 is an interior
        // node and survives while its child exists.
        let ev = idx.evict_lru(1, |p| p != 2);
        assert_eq!(ev, vec![1]);
        assert_eq!(idx.num_pages(), 2);

        // Now page 0 is a leaf and evictable; drain everything.
        let ev = idx.evict_lru(10, |_| true);
        assert_eq!(ev.len(), 2);
        assert!(ev.contains(&0) && ev.contains(&2));
        assert!(idx.is_empty());
        assert_eq!(idx.num_pages(), 0);
    }

    #[test]
    fn eviction_order_follows_recency() {
        let mut idx = RadixPrefixIndex::new(1);
        idx.insert(&[1], &[0]);
        idx.insert(&[2], &[1]);
        idx.insert(&[3], &[2]);
        idx.lookup(&[1]); // page 0 most recent
        let ev = idx.evict_lru(2, |_| true);
        assert_eq!(ev, vec![1, 2]); // coldest first, hot page 0 survives
        assert_eq!(idx.peek(&[1]).pages, vec![0]);
    }

    #[test]
    fn repeatedly_hit_prefix_survives_eviction_while_cold_one_goes() {
        // The engine regression this guards: `lookup` (not `peek`) must be
        // used on the hit path, otherwise eviction degrades to insertion
        // order and a hot system prompt inserted first is evicted before a
        // cold one-off prompt inserted later.
        let mut idx = RadixPrefixIndex::new(2);
        idx.insert(&[1, 1, 1, 2], &[0, 1]); // hot chain, inserted first
        idx.insert(&[7, 7], &[2]); // cold prompt, inserted later
        for _ in 0..4 {
            idx.lookup(&[1, 1, 1, 2]); // repeated hits keep the chain warm
        }
        let ev = idx.evict_lru(1, |_| true);
        assert_eq!(ev, vec![2], "the cold prefix is evicted first");
        assert_eq!(idx.peek(&[1, 1, 1, 2]).pages, vec![0, 1], "hot chain intact");

        // `peek` must NOT refresh recency: peeking the cold survivor of a
        // fresh pair leaves it coldest and it still goes first.
        let mut idx = RadixPrefixIndex::new(1);
        idx.insert(&[5], &[0]);
        idx.insert(&[6], &[1]);
        idx.peek(&[5]); // no LRU bump
        idx.lookup(&[6]);
        assert_eq!(idx.evict_lru(1, |_| true), vec![0]);
    }

    #[test]
    fn partial_page_probe_matches_nothing() {
        let mut idx = RadixPrefixIndex::new(4);
        idx.insert(&[1, 2, 3, 4], &[0]);
        // 3 tokens < one page: nothing shareable.
        assert_eq!(idx.peek(&[1, 2, 3]), PrefixMatch::default());
    }

    #[test]
    fn stats_cover_shape_pages_and_hit_depths() {
        let mut idx = RadixPrefixIndex::new(2);
        let empty = idx.stats();
        assert_eq!((empty.pages, empty.max_depth, empty.lookups), (0, 0, 0));
        assert!(empty.depth_hist.is_empty());

        // Two chains off a shared root chunk plus a separate root:
        //   [5,6] -> [1,1]        (pages 0 -> 1)
        //   [5,6] -> [2,2]        (pages 0 -> 2)
        //   [9,9]                 (page 3)
        idx.insert(&[5, 6, 1, 1], &[0, 1]);
        idx.insert(&[5, 6, 2, 2], &[0, 2]);
        idx.insert(&[9, 9], &[3]);
        let mut pages = idx.pages();
        pages.sort_unstable();
        assert_eq!(pages, vec![0, 1, 2, 3]);

        idx.lookup(&[5, 6, 1, 1]); // depth 2 hit
        idx.lookup(&[9, 9]); // depth 1 hit
        idx.lookup(&[4, 4]); // miss
        idx.peek(&[5, 6]); // peek must not count as a lookup

        let s = idx.stats();
        assert_eq!(s.pages, 4);
        assert_eq!(s.max_depth, 2);
        assert_eq!(s.depth_hist, vec![2, 2], "2 roots, 2 depth-1 leaves");
        assert_eq!(s.branching_hist, vec![3, 0, 1], "3 leaves, one 2-way node");
        assert_eq!(s.hit_depth_hist, vec![1, 1, 1]);
        assert_eq!(s.lookups, 3);
        assert_eq!(s.depth_hist.iter().sum::<u64>(), s.pages as u64);
    }
}
