//! Cascade (shared-prefix) schedule simulation.
//!
//! Extends the discrete CTA model of [`super::schedule`] to cascade
//! plans: a shared-prefix segment's LeanTiles stream the same K/V bytes
//! as any other tile but serve every member query at once, so the modeled
//! HBM traffic of a batch with a common prefix drops by
//! `(members - 1) × prefix_tiles` tile-streams per head — the bandwidth
//! win `leanattn simulate --shared-prefix` and `benches/cascade.rs`
//! quantify. Reduction follows stream-K (host CTA folds peer partials
//! in-kernel), plus one final rescale per output row that merges its
//! shared-prefix partial with its suffix partial.
//!
//! Tiles are priced per **KV head**: under GQA/MQA one KV stream serves a
//! whole query-head group, so modeled KV bytes divide by the group size
//! (`queries_of` scales the per-tile compute up by the same factor) —
//! ungrouped problems (`kv_heads == heads`) price exactly as before.

use crate::partition::cascade::{build_cascade_plan, CascadeProblem, SegKind};
use crate::partition::plan::Strategy;

use super::arch::GpuArch;
use super::cost::{kv_stream_bytes, TileCost};
use super::schedule::list_schedule;

/// Simulation outcome for a cascade problem, with the flat stream-K
/// baseline's traffic for comparison.
#[derive(Clone, Debug)]
pub struct CascadeSimResult {
    pub latency_us: f64,
    /// Busy-slot time over makespan × slots.
    pub occupancy: f64,
    pub grid: usize,
    /// Time attributable to reductions and the final per-output merges.
    pub reduce_us: f64,
    /// Modeled HBM bytes the cascade plan streams (shared prefix counted
    /// once per group).
    pub kv_bytes: f64,
    /// Modeled HBM bytes the flat plan streams (prefix re-streamed per
    /// member sequence).
    pub baseline_kv_bytes: f64,
}

impl CascadeSimResult {
    /// Fraction of baseline KV traffic the cascade plan avoids.
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.baseline_kv_bytes <= 0.0 {
            return 0.0;
        }
        1.0 - self.kv_bytes / self.baseline_kv_bytes
    }
}

/// Modeled KV bytes of a cascade problem (shared tiles counted once).
pub fn cascade_kv_bytes(problem: &CascadeProblem) -> f64 {
    kv_stream_bytes(
        problem.segment_problem().total_tiles(),
        problem.tile,
        problem.head_dim,
    )
}

/// Modeled KV bytes of the flat (no sharing) plan for the same batch.
pub fn baseline_kv_bytes(problem: &CascadeProblem) -> f64 {
    kv_stream_bytes(
        problem.baseline_problem().total_tiles(),
        problem.tile,
        problem.head_dim,
    )
}

/// Plan + simulate a cascade problem on `arch`.
pub fn simulate_cascade(problem: &CascadeProblem, arch: &GpuArch) -> CascadeSimResult {
    let slots = arch.sm_slots();
    let cplan = build_cascade_plan(problem, slots);
    let plan = &cplan.plan;

    // Per-CTA compute durations: a segment's per-tile cost depends on how
    // many query rows its group's KV stream serves.
    let durations: Vec<f64> = plan
        .ctas
        .iter()
        .map(|cta| {
            cta.segments
                .iter()
                .map(|seg| {
                    let cost = TileCost::with_queries(
                        arch,
                        plan.tile,
                        problem.head_dim,
                        Strategy::Cascade,
                        problem.queries_of(seg.group as usize),
                    );
                    let mut t = cost.segment_setup_us
                        + seg.tile_count as f64 * cost.tile_us;
                    if !(seg.is_host && seg.is_finishing) {
                        t += arch.partial_store_us;
                    }
                    t
                })
                .sum()
        })
        .collect();

    let busy_compute: f64 = durations.iter().sum();
    let (finish, compute_makespan) = list_schedule(&durations, slots);

    // Stream-K in-kernel reduction over segment-problem groups.
    let groups = plan.groups;
    let mut host_of: Vec<Option<usize>> = vec![None; groups];
    let mut peers_of: Vec<Vec<usize>> = vec![Vec::new(); groups];
    for (ci, cta) in plan.ctas.iter().enumerate() {
        for seg in &cta.segments {
            if seg.is_host {
                host_of[seg.group as usize] = Some(ci);
            } else {
                peers_of[seg.group as usize].push(ci);
            }
        }
    }
    let mut busy_reduce = 0.0f64;
    let mut total = compute_makespan;
    let mut reduce_us = 0.0f64;
    for g in 0..groups {
        let Some(h) = host_of[g] else { continue };
        if peers_of[g].is_empty() {
            continue;
        }
        let peers_done = peers_of[g]
            .iter()
            .map(|&p| finish[p])
            .fold(0.0f64, f64::max);
        // A shared group's host folds each peer partial once per member
        // row (the fold is vectorized over rows but still moves them).
        let rows = problem.queries_of(g) as f64;
        let fold = peers_of[g].len() as f64 * arch.reduce_per_partial_us * rows;
        let done = finish[h].max(peers_done) + fold;
        busy_reduce += fold;
        if done > total {
            reduce_us = reduce_us.max(done - compute_makespan);
            total = total.max(done);
        }
    }

    // Final cascade merge: every output row with both a shared-prefix
    // contribution and a non-empty suffix folds the two partials once.
    let mut merges = 0usize;
    for g in 0..groups {
        if let SegKind::Shared { pg, head: _ } = problem.seg_kind(g) {
            for &m in &problem.prefix_groups[pg].members {
                if problem.ctx_lens[m as usize] > problem.prefix_of(m as usize) {
                    merges += 1;
                }
            }
        }
    }
    let merge_work = merges as f64 * arch.reduce_per_partial_us;
    let merge_us = merge_work / slots.min(merges.max(1)) as f64;
    busy_reduce += merge_work;
    reduce_us += merge_us;
    let latency_compute = total + merge_us;

    let latency_us = latency_compute + arch.kernel_launch_us;
    let busy = busy_compute + busy_reduce;
    let denom = latency_compute.max(1e-12) * slots as f64;

    CascadeSimResult {
        latency_us,
        occupancy: (busy / denom).min(1.0),
        grid: plan.grid(),
        reduce_us,
        kv_bytes: cascade_kv_bytes(problem),
        baseline_kv_bytes: baseline_kv_bytes(problem),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::cascade::PrefixGroup;
    use crate::partition::plan::{DecodeProblem, Strategy};
    use crate::sim::schedule::simulate;

    fn shared_batch(batch: usize, prefix: u32, suffix: u32) -> CascadeProblem {
        CascadeProblem::new(
            8,
            vec![prefix + suffix; batch],
            64,
            vec![PrefixGroup {
                prefix_len: prefix,
                members: (0..batch as u32).collect(),
            }],
        )
        .unwrap()
    }

    #[test]
    fn shared_prefix_streams_strictly_fewer_bytes() {
        for batch in [2usize, 4, 8, 16] {
            let p = shared_batch(batch, 65536, 1024);
            let r = simulate_cascade(&p, &GpuArch::a100());
            assert!(
                r.kv_bytes < r.baseline_kv_bytes,
                "batch {batch}: cascade {} >= baseline {}",
                r.kv_bytes,
                r.baseline_kv_bytes
            );
            // Savings grow with the number of sequences sharing the prefix.
            let expect = 1.0 - (1.0 / batch as f64);
            assert!(
                (r.bytes_saved_fraction() - expect).abs() < 0.05,
                "batch {batch}: saved {:.3}, expected ~{expect:.3}",
                r.bytes_saved_fraction()
            );
        }
    }

    #[test]
    fn gqa_shrinks_modeled_kv_traffic_by_the_group_size() {
        // 8 query heads over 2 kv heads: one quarter the KV streams of
        // the ungrouped batch, on both the cascade and flat plans — so
        // the shared-prefix savings *fraction* is unchanged.
        let dense = shared_batch(8, 65536, 1024);
        let grouped = shared_batch(8, 65536, 1024).with_kv_heads(2);
        assert!(
            (cascade_kv_bytes(&grouped) * 4.0 - cascade_kv_bytes(&dense)).abs()
                < 1e-6 * cascade_kv_bytes(&dense)
        );
        assert!(
            (baseline_kv_bytes(&grouped) * 4.0 - baseline_kv_bytes(&dense)).abs()
                < 1e-6 * baseline_kv_bytes(&dense)
        );
        let arch = GpuArch::a100();
        let rd = simulate_cascade(&dense, &arch);
        let rg = simulate_cascade(&grouped, &arch);
        assert!(
            (rd.bytes_saved_fraction() - rg.bytes_saved_fraction()).abs() < 1e-9,
            "saved {:.4} vs {:.4}",
            rd.bytes_saved_fraction(),
            rg.bytes_saved_fraction()
        );
    }

    #[test]
    fn cascade_latency_beats_flat_stream_k_on_shared_batches() {
        let p = shared_batch(8, 65536, 1024);
        let arch = GpuArch::a100();
        let cascade = simulate_cascade(&p, &arch);
        let flat = simulate(&p.baseline_problem(), Strategy::StreamK, &arch);
        assert!(
            cascade.latency_us < flat.latency_us,
            "cascade {} vs flat {}",
            cascade.latency_us,
            flat.latency_us
        );
    }

    #[test]
    fn no_sharing_degenerates_to_stream_k() {
        let p = CascadeProblem::new(8, vec![4096; 4], 64, vec![]).unwrap();
        let r = simulate_cascade(&p, &GpuArch::a100());
        assert!((r.kv_bytes - r.baseline_kv_bytes).abs() < 1e-6);
        let flat = simulate(
            &DecodeProblem::uniform(4, 8, 4096, 64),
            Strategy::StreamK,
            &GpuArch::a100(),
        );
        // Same tile space, same scheduler: latencies agree closely.
        let ratio = r.latency_us / flat.latency_us;
        assert!((0.9..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn occupancy_stays_high() {
        let p = shared_batch(4, 131_072, 2048);
        let r = simulate_cascade(&p, &GpuArch::a100());
        assert!(r.occupancy > 0.85, "occupancy {}", r.occupancy);
        assert!(r.grid <= GpuArch::a100().sm_slots());
    }
}
