//! Inference-phase timeshare model (paper Fig 2) and the end-to-end
//! speedup projection (Fig 12).
//!
//! For a prompt of `P` tokens generating `P/ratio` output tokens:
//!
//! * **Prefill** is compute-bound: `FLOPs / (peak × efficiency)`.
//! * **Decode linear layers** (QKV, MLP) are weight-streaming bound:
//!   `param_bytes / HBM bandwidth` per step (the paper notes these are
//!   INT8-quantized and Stream-K-optimized, so they are *not* the
//!   bottleneck — we model them at full bandwidth efficiency).
//! * **Decode attention** is the contested part: per-step latency comes
//!   from the schedule simulator under the chosen partitioning strategy.

use super::arch::GpuArch;
use super::schedule::simulate;
use crate::model::ModelConfig;
use crate::partition::plan::{DecodeProblem, Strategy};

/// Breakdown of one full inference (prefill + all decode steps), seconds.
#[derive(Clone, Debug)]
pub struct Timeshare {
    pub prefill_s: f64,
    pub decode_qkv_mlp_s: f64,
    pub decode_attention_s: f64,
    pub output_tokens: usize,
}

impl Timeshare {
    pub fn total_s(&self) -> f64 {
        self.prefill_s + self.decode_qkv_mlp_s + self.decode_attention_s
    }

    pub fn decode_fraction(&self) -> f64 {
        (self.decode_qkv_mlp_s + self.decode_attention_s) / self.total_s()
    }

    pub fn attention_fraction_of_decode(&self) -> f64 {
        self.decode_attention_s / (self.decode_qkv_mlp_s + self.decode_attention_s)
    }
}

/// Fraction of peak FLOPs the prefill linear layers achieve (the paper
/// cites FA2 reaching 50-70%; dense GEMMs do better).
const PREFILL_EFF: f64 = 0.6;
/// INT8 weight quantization halves streamed bytes for the linear layers.
const LINEAR_WEIGHT_BYTES: f64 = 1.0;
/// How often to re-simulate attention along the decode trajectory (the
/// context grows by one token per step; sampling keeps this cheap).
const ATTN_SAMPLES: usize = 16;

/// Model one inference of `prompt` tokens producing `prompt/ratio` output
/// tokens at batch size `batch`, with decode attention executed under
/// `strategy`.
pub fn timeshare(
    cfg: &ModelConfig,
    arch: &GpuArch,
    prompt: usize,
    ratio: usize,
    batch: usize,
    strategy: Strategy,
) -> Timeshare {
    let out_tokens = (prompt / ratio).max(1);

    // Prefill: compute-bound over the whole batch.
    let prefill_flops = cfg.prefill_flops(prompt as u64) as f64 * batch as f64;
    let prefill_s = prefill_flops / (arch.peak_tflops * 1e12 * PREFILL_EFF);

    // Decode linear layers: weight streaming once per step (batch shares
    // the stream), plus activation traffic (negligible).
    let weight_bytes = cfg.param_count() as f64 * LINEAR_WEIGHT_BYTES;
    let per_step_linear_s = weight_bytes / (arch.hbm_bw_gbs * 1e9);
    let decode_qkv_mlp_s = per_step_linear_s * out_tokens as f64;

    // Decode attention: sample the growing context and integrate. Each
    // layer's attention is its own kernel launch over `n_heads` output
    // tiles (the paper's per-layer execution; Phi-3 Medium = "40 heads").
    let mut decode_attention_s = 0.0;
    let samples = ATTN_SAMPLES.min(out_tokens);
    let step = (out_tokens as f64 / samples as f64).max(1.0);
    for i in 0..samples {
        let ctx = prompt + (i as f64 * step) as usize;
        let problem = DecodeProblem::uniform(batch, cfg.n_heads, ctx, cfg.head_dim);
        let r = simulate(&problem, resolve(strategy, &problem, arch), arch);
        decode_attention_s += r.latency_us * 1e-6 * step * cfg.n_layers as f64;
    }

    Timeshare { prefill_s, decode_qkv_mlp_s, decode_attention_s, output_tokens: out_tokens }
}

fn resolve(strategy: Strategy, problem: &DecodeProblem, arch: &GpuArch) -> Strategy {
    match strategy {
        Strategy::FixedSplit { splits: 0 } => Strategy::fixed_split_auto(problem, arch.num_sms),
        s => s,
    }
}

/// Sentinel for "FlashDecoding with its own heuristic".
pub const FD_AUTO: Strategy = Strategy::FixedSplit { splits: 0 };

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_dominates_at_8_to_1_ratio() {
        // Paper Fig 2: >50% of time in decode even at 8:1 prompt:output.
        let cfg = ModelConfig::phi3_medium();
        let arch = GpuArch::a100();
        let ts = timeshare(&cfg, &arch, 8192, 8, 1, FD_AUTO);
        assert!(
            ts.decode_fraction() > 0.5,
            "decode fraction {}",
            ts.decode_fraction()
        );
    }

    #[test]
    fn attention_share_grows_with_prompt() {
        let cfg = ModelConfig::phi3_medium();
        let arch = GpuArch::a100();
        let small = timeshare(&cfg, &arch, 2048, 8, 1, FD_AUTO);
        let large = timeshare(&cfg, &arch, 65536, 8, 1, FD_AUTO);
        assert!(
            large.attention_fraction_of_decode() > small.attention_fraction_of_decode()
        );
    }

    #[test]
    fn lean_e2e_speedup_grows_with_context() {
        // Paper Fig 12: modest speedup at 1k outputs, larger beyond 16k.
        let cfg = ModelConfig::phi3_medium();
        let arch = GpuArch::a100();
        let speed = |prompt: usize| {
            let fd = timeshare(&cfg, &arch, prompt, 8, 1, FD_AUTO);
            let la = timeshare(&cfg, &arch, prompt, 8, 1, Strategy::StreamK);
            fd.total_s() / la.total_s()
        };
        let s_small = speed(8192);
        let s_large = speed(131_072);
        assert!(s_small >= 1.0, "small-prompt speedup {s_small}");
        assert!(s_large > s_small, "speedup grows: {s_small} -> {s_large}");
    }
}
