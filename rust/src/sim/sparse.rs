//! Sparse-selection decode simulation: bytes saved and attention-mass
//! coverage vs page budget.
//!
//! The sparse subsystem's bargain is bytes-for-coverage: a decode step
//! that streams `budget` of `P` context pages reads a `budget / P`
//! fraction of the dense KV traffic but only covers whatever attention
//! mass those pages hold. This model prices both sides: the pruned
//! stream runs through the same stream-K schedule simulator as every
//! dense figure (so latency and occupancy follow the paper's execution
//! model), while coverage follows the standard long-context shape —
//! attention sinks and the recency window hold fixed shares of the mass,
//! and the middle pages' mass decays geometrically by relevance rank,
//! which a sound upper-bound selector recovers top-first. `leanattn
//! simulate --sparse-budget` renders this trade-off.

use crate::partition::plan::{DecodeProblem, Strategy};
use crate::sparse::SparsePolicy;

use super::arch::GpuArch;
use super::cost::kv_stream_bytes;
use super::schedule::simulate;

/// Attention-mass share held by the sink pages (fixed, per the
/// attention-sink literature) when selection engages.
const SINK_MASS: f64 = 0.3;
/// Attention-mass share held by the recency window.
const WINDOW_MASS: f64 = 0.2;

/// One sparse-decode modeling case.
#[derive(Clone, Copy, Debug)]
pub struct SparseDecodeCase {
    pub batch: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// Context tokens per sequence.
    pub ctx: usize,
    pub page_tokens: usize,
    pub policy: SparsePolicy,
    /// Geometric decay of middle-page attention mass by relevance rank
    /// (in `(0, 1)`; smaller = more concentrated = easier to cover).
    pub mass_alpha: f64,
}

/// Modeled outcome of one sparse-vs-dense decode step.
#[derive(Clone, Copy, Debug)]
pub struct SparseSimResult {
    /// Modeled attention latency of the dense step (us).
    pub dense_us: f64,
    /// Modeled attention latency over the selected pages only (us).
    pub sparse_us: f64,
    /// HBM KV bytes the dense step streams.
    pub dense_kv_bytes: f64,
    /// HBM KV bytes the selected pages stream.
    pub sparse_kv_bytes: f64,
    /// Modeled attention-mass coverage of the selection, `(0, 1]`.
    pub coverage: f64,
    /// Context pages per sequence.
    pub pages_total: usize,
    /// Pages each sequence streams under the policy.
    pub pages_selected: usize,
}

impl SparseSimResult {
    pub fn speedup(&self) -> f64 {
        if self.sparse_us <= 0.0 {
            return 1.0;
        }
        self.dense_us / self.sparse_us
    }

    /// Fraction of dense KV traffic the selection avoids.
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.dense_kv_bytes <= 0.0 {
            return 0.0;
        }
        1.0 - self.sparse_kv_bytes / self.dense_kv_bytes
    }
}

/// Model one decode step of `case` on `arch`, dense vs selected pages.
pub fn simulate_sparse_decode(case: &SparseDecodeCase, arch: &GpuArch) -> SparseSimResult {
    let pages = case.ctx.div_ceil(case.page_tokens).max(1);
    let p = &case.policy;
    // The selected-page count comes from the policy itself
    // ([`SparsePolicy::effective_pages`]) — the same arithmetic the real
    // selector runs, so model and selector cannot drift.
    let selected = p.effective_pages(pages);
    let coverage = if selected >= pages {
        1.0
    } else {
        let (sink, window) = p.retention(pages);
        let k = (selected - sink - window) as i32;
        let middle = (pages - sink - window) as i32;
        let a = case.mass_alpha.clamp(1e-6, 1.0 - 1e-9);
        // Share of the middle mass the top-k relevance ranks hold.
        let covered_middle = (1.0 - a.powi(k)) / (1.0 - a.powi(middle));
        SINK_MASS + WINDOW_MASS + (1.0 - SINK_MASS - WINDOW_MASS) * covered_middle
    };
    // Selected token count: with a retained window the partial tail (if
    // any) survives and every pruned page is a full middle page; with
    // `window_pages == 0` the tail is an ordinary middle candidate, and
    // this model — whose upper-bound selector has no recency term —
    // prices it as pruned, so every selected page is full.
    let (_, window) = p.retention(pages);
    let partial = case.ctx % case.page_tokens;
    let sel_tokens = if selected >= pages {
        case.ctx
    } else if window >= 1 || partial == 0 {
        case.ctx - (pages - selected) * case.page_tokens
    } else {
        (selected * case.page_tokens).min(case.ctx)
    };

    let dense_p = DecodeProblem::uniform(case.batch, case.heads, case.ctx, case.head_dim);
    let sparse_p =
        DecodeProblem::uniform(case.batch, case.heads, sel_tokens, case.head_dim);
    let dense = simulate(&dense_p, Strategy::StreamK, arch);
    let sparse = simulate(&sparse_p, Strategy::StreamK, arch);
    SparseSimResult {
        dense_us: dense.latency_us,
        sparse_us: sparse.latency_us,
        dense_kv_bytes: kv_stream_bytes(dense_p.total_tiles(), dense_p.tile, case.head_dim),
        sparse_kv_bytes: kv_stream_bytes(
            sparse_p.total_tiles(),
            sparse_p.tile,
            case.head_dim,
        ),
        coverage,
        pages_total: pages,
        pages_selected: selected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(ctx: usize, budget: usize) -> SparseDecodeCase {
        SparseDecodeCase {
            batch: 4,
            heads: 32,
            head_dim: 64,
            ctx,
            page_tokens: 16,
            policy: SparsePolicy::with_budget(budget),
            mass_alpha: 0.85,
        }
    }

    #[test]
    fn sub_budget_streams_strictly_fewer_bytes_and_wins_latency() {
        let arch = GpuArch::a100();
        let r = simulate_sparse_decode(&case(524_288, 16), &arch);
        assert!(r.sparse_kv_bytes < r.dense_kv_bytes);
        assert!(r.bytes_saved_fraction() > 0.9, "{}", r.bytes_saved_fraction());
        assert!(r.speedup() > 1.0, "speedup {}", r.speedup());
        assert!(r.coverage > 0.5 && r.coverage < 1.0, "{}", r.coverage);
        assert_eq!(r.pages_selected, 16);
        assert_eq!(r.pages_total, 32_768);
    }

    #[test]
    fn covering_budget_degenerates_to_dense() {
        let arch = GpuArch::a100();
        let pages = 4096 / 16;
        let r = simulate_sparse_decode(&case(4096, pages), &arch);
        assert_eq!(r.pages_selected, r.pages_total);
        assert_eq!(r.coverage, 1.0);
        assert!((r.sparse_kv_bytes - r.dense_kv_bytes).abs() < 1e-9);
        assert!((r.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn coverage_and_bytes_are_monotone_in_the_budget() {
        let arch = GpuArch::a100();
        let mut last_cov = 0.0;
        let mut last_bytes = 0.0;
        for budget in [8usize, 32, 128, 512, 2048] {
            let r = simulate_sparse_decode(&case(65_536, budget), &arch);
            assert!(r.coverage >= last_cov, "coverage dipped at {budget}");
            assert!(r.sparse_kv_bytes >= last_bytes, "bytes dipped at {budget}");
            last_cov = r.coverage;
            last_bytes = r.sparse_kv_bytes;
        }
    }

    #[test]
    fn windowless_policies_price_the_partial_tail_as_pruned() {
        // ctx 1025 over 512-token pages (two full + a 1-token tail) with
        // sink 1, window 0, budget 1: the selector keeps the full sink
        // page and may drop the tail, so the model must count 512
        // selected tokens (2 of the 5 dense 256-token LeanTiles), not 1.
        let arch = GpuArch::a100();
        let c = SparseDecodeCase {
            batch: 1,
            heads: 2,
            head_dim: 64,
            ctx: 1025,
            page_tokens: 512,
            policy: SparsePolicy {
                budget_pages: 1,
                sink_pages: 1,
                window_pages: 0,
                dense_threshold_pages: 0,
            },
            mass_alpha: 0.85,
        };
        let r = simulate_sparse_decode(&c, &arch);
        assert_eq!(r.pages_selected, 1);
        assert!(
            (r.bytes_saved_fraction() - 0.6).abs() < 1e-9,
            "2 of 5 tiles must stream, got {}",
            r.bytes_saved_fraction()
        );
    }

    #[test]
    fn dense_threshold_bypasses_short_contexts() {
        let arch = GpuArch::a100();
        let mut c = case(512, 8); // 32 pages, budget 8
        c.policy.dense_threshold_pages = 64;
        let r = simulate_sparse_decode(&c, &arch);
        assert_eq!(r.pages_selected, r.pages_total, "below threshold = dense");
        assert_eq!(r.coverage, 1.0);
    }
}
