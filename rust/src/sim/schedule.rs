//! Discrete CTA-level schedule simulation.
//!
//! Executes a [`Plan`] on a [`GpuArch`]: CTAs are list-scheduled onto the
//! device's co-resident CTA slots in launch order (the hardware's wave
//! behaviour), each running its LeanTile segments sequentially. Reduction
//! is modelled per strategy:
//!
//! * FlashAttention-2 — none.
//! * FlashDecoding / FlashInfer — a *second kernel launch* whose CTAs
//!   (one per output tile with >1 partial) re-scale the partials.
//! * LeanAttention — in-kernel: the host CTA finishes when its own tiles
//!   *and* all peer partials are done, then folds them in (Alg 2 L24-39).
//!
//! Outputs latency, SM occupancy (busy-slot-time over makespan), wave
//! count and energy (busy/idle SM power integrated over the makespan).

use super::arch::GpuArch;
use super::cost::TileCost;
use crate::partition::plan::{build_plan, DecodeProblem, Plan, Strategy};

/// Simulation outcome for one (problem, strategy, arch) triple.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub strategy: Strategy,
    pub latency_us: f64,
    /// Busy-slot time / (compute makespan × slots); 1.0 = every SM busy
    /// the whole time (the paper's "quantization efficiency").
    pub occupancy: f64,
    pub energy_j: f64,
    pub grid: usize,
    /// Waves of the attention kernel (ceil(grid / slots) effective).
    pub waves: f64,
    /// Time attributable to reduction (incl. FD's second launch).
    pub reduce_us: f64,
    pub kernel_launches: usize,
}

impl SimResult {
    pub fn name(&self) -> &'static str {
        self.strategy.name()
    }
}

/// Plan + simulate in one step.
pub fn simulate(problem: &DecodeProblem, strategy: Strategy, arch: &GpuArch) -> SimResult {
    let slots = effective_slots(strategy, arch);
    let plan = build_plan(problem, strategy, slots);
    simulate_plan(&plan, problem, arch)
}

/// FlashInfer's scheduler can keep fewer CTAs resident (reserved buffer
/// management); everyone else gets the full device. Public so the
/// partition-balance report (`obs::balance`) plans and scores each
/// strategy with exactly the slot count the simulator schedules on.
pub fn effective_slots(strategy: Strategy, arch: &GpuArch) -> usize {
    match strategy {
        Strategy::PagedFixedSplit { .. } => {
            ((arch.sm_slots() as f64 * arch.fi_slot_fraction) as usize).max(1)
        }
        _ => arch.sm_slots(),
    }
}

/// Greedy list scheduling of `durations` onto `slots` identical slots in
/// index order. Returns per-CTA finish times and the makespan.
///
/// Invariants (property-tested in `rust/tests/balance_props.rs`):
/// makespan ≥ total/slots, makespan ≥ max duration, and the busy
/// fraction busy/(makespan·slots) lies in (0, 1] for non-empty input.
pub fn list_schedule(durations: &[f64], slots: usize) -> (Vec<f64>, f64) {
    assert!(slots > 0);
    let mut slot_free = vec![0.0f64; slots.min(durations.len()).max(1)];
    let mut finish = Vec::with_capacity(durations.len());
    for (i, &d) in durations.iter().enumerate() {
        // Hardware dispatches to the earliest-free slot; with equal frees,
        // round-robin. Scan is O(slots) but slots ≤ ~2k.
        let (si, &free) = slot_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(a.0.cmp(&b.0)))
            .unwrap();
        let _ = i;
        let end = free + d;
        slot_free[si] = end;
        finish.push(end);
    }
    let makespan = slot_free.iter().cloned().fold(0.0, f64::max);
    (finish, makespan)
}

/// Simulate an already-built plan.
pub fn simulate_plan(plan: &Plan, problem: &DecodeProblem, arch: &GpuArch) -> SimResult {
    let strategy = plan.strategy;
    let slots = effective_slots(strategy, arch);
    let cost = TileCost::new(arch, plan.tile, problem.head_dim, strategy);

    // Per-CTA compute duration: segments run back-to-back; non-host
    // segments additionally store their partial to global memory.
    let durations: Vec<f64> = plan
        .ctas
        .iter()
        .map(|cta| {
            cta.segments
                .iter()
                .map(|seg| {
                    let mut t = cost.segment_setup_us
                        + seg.tile_count as f64 * cost.tile_us;
                    if !(seg.is_host && seg.is_finishing) {
                        t += arch.partial_store_us;
                    }
                    t
                })
                .sum()
        })
        .collect();

    let busy_compute: f64 = durations.iter().sum();
    let (finish, compute_makespan) = list_schedule(&durations, slots);

    // group -> (host cta, peer ctas)
    let groups = plan.groups;
    let mut host_of: Vec<Option<usize>> = vec![None; groups];
    let mut peers_of: Vec<Vec<usize>> = vec![Vec::new(); groups];
    for (ci, cta) in plan.ctas.iter().enumerate() {
        for seg in &cta.segments {
            if seg.is_host {
                host_of[seg.group as usize] = Some(ci);
            } else {
                peers_of[seg.group as usize].push(ci);
            }
        }
    }

    let mut reduce_us = 0.0f64;
    let mut busy_reduce = 0.0f64;
    let mut kernel_launches = 1;

    let latency_compute = match strategy {
        Strategy::Dense => compute_makespan,
        Strategy::StreamK | Strategy::Cascade => {
            // In-kernel reduction: host completes when its own compute and
            // every peer partial are done, plus the fold cost.
            let mut total = compute_makespan;
            for g in 0..groups {
                let Some(h) = host_of[g] else { continue };
                if peers_of[g].is_empty() {
                    continue;
                }
                let peers_done = peers_of[g]
                    .iter()
                    .map(|&p| finish[p])
                    .fold(0.0f64, f64::max);
                let fold = peers_of[g].len() as f64 * arch.reduce_per_partial_us;
                let done = finish[h].max(peers_done) + fold;
                busy_reduce += fold;
                if done > total {
                    reduce_us = reduce_us.max(done - compute_makespan);
                    total = total.max(done);
                }
            }
            total
        }
        Strategy::FixedSplit { .. } | Strategy::PagedFixedSplit { .. } => {
            // Separate fix-up kernel: one reduce-CTA per group that has
            // more than one partial.
            let reduce_durs: Vec<f64> = (0..groups)
                .filter(|&g| !peers_of[g].is_empty())
                .map(|g| (peers_of[g].len() + 1) as f64 * arch.reduce_per_partial_us)
                .collect();
            if reduce_durs.is_empty() {
                compute_makespan
            } else {
                kernel_launches = 2;
                busy_reduce = reduce_durs.iter().sum();
                let (_, reduce_makespan) = list_schedule(&reduce_durs, slots);
                reduce_us = arch.kernel_launch_us + reduce_makespan;
                compute_makespan + arch.kernel_launch_us + reduce_makespan
            }
        }
    };

    let latency_us = latency_compute + arch.kernel_launch_us;
    let busy = busy_compute + busy_reduce;
    let denom = latency_compute.max(1e-12) * slots as f64;
    let occupancy = (busy / denom).min(1.0);
    let waves = plan.grid() as f64 / slots as f64;

    // Energy: SMs are busy for busy/max_ctas SM-time (co-resident CTAs
    // share an SM), idle otherwise; baseline board power over the run.
    let t = latency_us;
    let busy_sm_time = (busy / arch.max_ctas_per_sm as f64)
        .min(arch.num_sms as f64 * t);
    let idle_sm_time = arch.num_sms as f64 * t - busy_sm_time;
    let energy_j = (arch.base_w * t
        + arch.sm_busy_w * busy_sm_time
        + arch.sm_idle_w * idle_sm_time)
        * 1e-6;

    SimResult {
        strategy,
        latency_us,
        occupancy,
        energy_j,
        grid: plan.grid(),
        waves,
        reduce_us,
        kernel_launches,
    }
}

/// Per-CTA placement detail (for schedule visualisation — Fig 1).
#[derive(Clone, Debug)]
pub struct CtaTimeline {
    pub cta: usize,
    pub slot: usize,
    pub start_us: f64,
    pub finish_us: f64,
    /// Groups (output tiles) this CTA contributes to.
    pub groups: Vec<u32>,
}

/// List-schedule a plan and report each CTA's slot and time window.
pub fn schedule_detail(plan: &Plan, problem: &DecodeProblem, arch: &GpuArch) -> Vec<CtaTimeline> {
    let slots = effective_slots(plan.strategy, arch);
    let cost = TileCost::new(arch, plan.tile, problem.head_dim, plan.strategy);
    let mut slot_free = vec![0.0f64; slots];
    let mut out = Vec::with_capacity(plan.grid());
    for (ci, cta) in plan.ctas.iter().enumerate() {
        let dur: f64 = cta
            .segments
            .iter()
            .map(|seg| {
                cost.segment_setup_us
                    + seg.tile_count as f64 * cost.tile_us
                    + if seg.is_host && seg.is_finishing {
                        0.0
                    } else {
                        arch.partial_store_us
                    }
            })
            .sum();
        let (si, &free) = slot_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(a.0.cmp(&b.0)))
            .unwrap();
        slot_free[si] = free + dur;
        out.push(CtaTimeline {
            cta: ci,
            slot: si,
            start_us: free,
            finish_us: free + dur,
            groups: cta.segments.iter().map(|s| s.group).collect(),
        });
    }
    out
}

/// Convenience: simulate all four mechanisms on one problem.
pub fn simulate_all(problem: &DecodeProblem, arch: &GpuArch) -> Vec<SimResult> {
    let fd = Strategy::fixed_split_auto(problem, arch.num_sms);
    let fi_splits = match fd {
        Strategy::FixedSplit { splits } => splits,
        _ => 1,
    };
    vec![
        simulate(problem, Strategy::Dense, arch),
        simulate(problem, fd, arch),
        simulate(
            problem,
            Strategy::PagedFixedSplit { splits: fi_splits, page: 16 },
            arch,
        ),
        simulate(problem, Strategy::StreamK, arch),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100() -> GpuArch {
        GpuArch::a100()
    }

    #[test]
    fn list_schedule_basic() {
        let (finish, makespan) = list_schedule(&[3.0, 1.0, 2.0], 2);
        // slot0: 3.0; slot1: 1.0 then 2.0 -> finish 3.0
        assert_eq!(finish, vec![3.0, 1.0, 3.0]);
        assert_eq!(makespan, 3.0);
    }

    #[test]
    fn fa2_low_occupancy_in_decode() {
        // 1 batch x 8 heads on 108 SMs: paper Fig 3 — FA2 nearly idle.
        let p = DecodeProblem::uniform(1, 8, 65536, 64);
        let r = simulate(&p, Strategy::Dense, &a100());
        assert!(r.occupancy < 0.10, "occupancy {}", r.occupancy);
    }

    #[test]
    fn lean_near_full_occupancy() {
        let p = DecodeProblem::uniform(1, 8, 65536, 64);
        let r = simulate(&p, Strategy::StreamK, &a100());
        assert!(r.occupancy > 0.90, "occupancy {}", r.occupancy);
        assert_eq!(r.grid, 216);
    }

    #[test]
    fn lean_beats_fd_on_long_context_odd_heads() {
        // 56 heads, BS 2, 256k ctx (the paper's max-speedup point).
        let p = DecodeProblem::uniform(2, 56, 262_144, 64);
        let arch = a100();
        let fd = simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
        let la = simulate(&p, Strategy::StreamK, &arch);
        let speedup = fd.latency_us / la.latency_us;
        assert!(speedup > 1.3, "LA/FD speedup {speedup}");
        assert!(speedup < 3.0, "speedup within sane bounds {speedup}");
    }

    #[test]
    fn lean_never_slower_than_fa2_or_fd() {
        for (b, h, ctx) in [
            (1usize, 8usize, 1024usize),
            (4, 32, 65536),
            (8, 56, 4096),
            (1, 128, 262_144),
            (32, 32, 2048),
        ] {
            let p = DecodeProblem::uniform(b, h, ctx, 64);
            let arch = a100();
            let la = simulate(&p, Strategy::StreamK, &arch);
            let fa2 = simulate(&p, Strategy::Dense, &arch);
            let fd =
                simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
            // 5% slack for overhead modelling noise
            assert!(
                la.latency_us <= fa2.latency_us * 1.05,
                "b{b} h{h} ctx{ctx}: LA {} vs FA2 {}",
                la.latency_us,
                fa2.latency_us
            );
            assert!(
                la.latency_us <= fd.latency_us * 1.05,
                "b{b} h{h} ctx{ctx}: LA {} vs FD {}",
                la.latency_us,
                fd.latency_us
            );
        }
    }

    #[test]
    fn fd_two_kernel_launches_when_split() {
        let p = DecodeProblem::uniform(1, 8, 65536, 64);
        let arch = a100();
        let fd = simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
        assert_eq!(fd.kernel_launches, 2);
        let la = simulate(&p, Strategy::StreamK, &arch);
        assert_eq!(la.kernel_launches, 1);
    }

    #[test]
    fn flashinfer_slower_than_fd_at_long_ctx() {
        let p = DecodeProblem::uniform(4, 32, 262_144, 64);
        let arch = a100();
        let results = simulate_all(&p, &arch);
        let fd = &results[1];
        let fi = &results[2];
        assert!(fi.latency_us > fd.latency_us, "FI should trail FD");
    }

    #[test]
    fn energy_tracks_idleness() {
        // Same work, FA2 leaves SMs idle -> more energy than LA (Fig 13).
        let p = DecodeProblem::uniform(1, 56, 262_144, 64);
        let arch = a100();
        let la = simulate(&p, Strategy::StreamK, &arch);
        let fd = simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
        assert!(fd.energy_j > la.energy_j, "FD {} vs LA {}", fd.energy_j, la.energy_j);
    }

    #[test]
    fn multi_gpu_zero_idle_for_lean() {
        // Paper Fig 9: 256 heads x 4 batch on 864 SMs — FD wastes the
        // 52-SM tail wave, LA does not.
        let p = DecodeProblem::uniform(4, 256, 262_144, 64);
        let arch = a100().multi(8);
        let la = simulate(&p, Strategy::StreamK, &arch);
        let fd = simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
        assert!(la.occupancy > 0.95);
        assert!(fd.latency_us / la.latency_us > 1.2);
    }
}
