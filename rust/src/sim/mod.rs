//! GPU execution-model simulator.
//!
//! The paper's claims — occupancy, wave quantization, speedup over
//! FlashDecoding/FlashInfer, energy — are *scheduling* properties of how
//! CTAs map onto SMs, not properties of the arithmetic. This module
//! executes the exact CTA→LeanTile assignments a [`crate::partition::Plan`]
//! describes on a discrete model of an A100/H100-class device and reports
//! latency, occupancy and energy. Absolute microseconds are calibrated
//! (DESIGN.md §Hardware-Adaptation); the *shapes* — who wins, by what
//! factor, where the crossovers sit — are the reproduction target.

pub mod arch;
pub mod cascade;
pub mod cost;
pub mod sampling;
pub mod schedule;
pub mod sparse;
pub mod spec;
pub mod timeshare;

pub use arch::GpuArch;
pub use cascade::{simulate_cascade, CascadeSimResult};
pub use cost::{CostCoefficients, TileCost};
pub use sampling::{simulate_fork_decode, ForkDecodeCase, ForkDecodeResult};
pub use schedule::{
    effective_slots, list_schedule, schedule_detail, simulate, simulate_all,
    simulate_plan, CtaTimeline, SimResult,
};
pub use sparse::{simulate_sparse_decode, SparseDecodeCase, SparseSimResult};
pub use spec::{
    expected_tokens_per_pass, simulate_spec_decode, SpecDecodeCase, SpecSimResult,
};
