//! Device descriptors. Numbers for A100/H100 come from the public spec
//! sheets and the microbenchmarking literature the paper cites ([13],
//! [21], [28]); the per-event overheads are calibration constants fitted
//! so the simulator reproduces the paper's measured speedup *ratios*
//! (documented in DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md).

/// A (possibly multi-) GPU execution target.
#[derive(Clone, Debug)]
pub struct GpuArch {
    pub name: String,
    /// Streaming multiprocessors (compute units) across all GPUs.
    pub num_sms: usize,
    /// Co-resident attention CTAs per SM (shared-memory limited; 2 for the
    /// 256-token LeanTile on A100 — paper §IV-C).
    pub max_ctas_per_sm: usize,
    /// Aggregate HBM bandwidth, GB/s (per GPU × num GPUs).
    pub hbm_bw_gbs: f64,
    /// Peak dense bf16/fp16 TFLOP/s (used by the timeshare model).
    pub peak_tflops: f64,
    /// Cost of launching one kernel (FlashDecoding pays this twice:
    /// attention + reduction kernel; LeanAttention once — §IV-C).
    pub kernel_launch_us: f64,
    /// Host-CTA cost to load + re-scale one peer partial (Alg 2 L29-35).
    pub reduce_per_partial_us: f64,
    /// Non-host CTA cost to store `(O~, m, l)` to global memory + signal.
    pub partial_store_us: f64,
    /// Per-SM dynamic power when busy / idle-but-clocked, and baseline
    /// board power (W) — for the Fig 13 energy model.
    pub sm_busy_w: f64,
    pub sm_idle_w: f64,
    pub base_w: f64,
    /// Bandwidth-efficiency multiplier (>1 = slower) for paged KV gathers
    /// (FlashInfer's 16-token pages vs contiguous streams).
    pub paged_gather_penalty: f64,
    /// Fraction of CTA slots FlashInfer's batch scheduler can actually
    /// fill (its reserved buffers/metadata CTAs; fitted to the paper's
    /// FI-vs-FD gap).
    pub fi_slot_fraction: f64,
}

impl GpuArch {
    /// Nvidia A100-80GB (SXM): 108 SMs, 2039 GB/s, 312 TFLOPs bf16.
    pub fn a100() -> GpuArch {
        GpuArch {
            name: "A100-80GB".into(),
            num_sms: 108,
            max_ctas_per_sm: 2,
            hbm_bw_gbs: 2039.0,
            peak_tflops: 312.0,
            kernel_launch_us: 5.0,
            reduce_per_partial_us: 0.12,
            partial_store_us: 0.10,
            sm_busy_w: 2.6,
            sm_idle_w: 0.9,
            base_w: 90.0,
            paged_gather_penalty: 1.35,
            fi_slot_fraction: 0.55,
        }
    }

    /// Nvidia H100-SXM-80GB: 132 SMs, 3350 GB/s, 990 TFLOPs bf16.
    pub fn h100() -> GpuArch {
        GpuArch {
            name: "H100-SXM-80GB".into(),
            num_sms: 132,
            max_ctas_per_sm: 2,
            hbm_bw_gbs: 3350.0,
            peak_tflops: 990.0,
            kernel_launch_us: 4.0,
            reduce_per_partial_us: 0.10,
            partial_store_us: 0.08,
            sm_busy_w: 3.6,
            sm_idle_w: 1.2,
            base_w: 110.0,
            paged_gather_penalty: 1.5,
            fi_slot_fraction: 0.45,
        }
    }

    /// Tensor-parallel scale-out: `n` identical GPUs. Attention heads are
    /// sharded across GPUs (§III-D), so the SM pool and bandwidth scale
    /// linearly; per-event overheads stay per-GPU.
    pub fn multi(&self, n: usize) -> GpuArch {
        assert!(n >= 1);
        GpuArch {
            name: format!("{}x{}", n, self.name),
            num_sms: self.num_sms * n,
            hbm_bw_gbs: self.hbm_bw_gbs * n as f64,
            peak_tflops: self.peak_tflops * n as f64,
            ..self.clone()
        }
    }

    /// Hypothetical 5-SM device from Fig 1 (for the schedule illustration).
    pub fn toy(num_sms: usize) -> GpuArch {
        GpuArch {
            name: format!("toy-{num_sms}sm"),
            num_sms,
            max_ctas_per_sm: 1,
            hbm_bw_gbs: 100.0,
            peak_tflops: 10.0,
            kernel_launch_us: 0.0,
            reduce_per_partial_us: 0.1,
            partial_store_us: 0.05,
            sm_busy_w: 1.0,
            sm_idle_w: 0.3,
            base_w: 0.0,
            paged_gather_penalty: 1.0,
            fi_slot_fraction: 1.0,
        }
    }

    /// Total co-resident CTA slots (the stream-K grid size, Eq. 2).
    pub fn sm_slots(&self) -> usize {
        self.num_sms * self.max_ctas_per_sm
    }

    /// Per-CTA-slot sustained memory bandwidth (GB/s). Each SM's LSU path
    /// sustains roughly its fair share of HBM bandwidth; co-resident CTAs
    /// split it.
    pub fn slot_bw_gbs(&self) -> f64 {
        self.hbm_bw_gbs / (self.num_sms as f64 * self.max_ctas_per_sm as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_matches_paper_constants() {
        let a = GpuArch::a100();
        assert_eq!(a.num_sms, 108);
        assert_eq!(a.sm_slots(), 216); // paper: 108 x 2 = 216 grid
    }

    #[test]
    fn h100_sm_count() {
        assert_eq!(GpuArch::h100().num_sms, 132);
    }

    #[test]
    fn multi_scales_linearly() {
        let m = GpuArch::a100().multi(8);
        assert_eq!(m.num_sms, 864); // paper: 8x108 = 864 compute cores
        assert!((m.hbm_bw_gbs - 8.0 * 2039.0).abs() < 1e-9);
        assert_eq!(m.max_ctas_per_sm, 2);
    }

    #[test]
    fn slot_bandwidth_partitioned() {
        let a = GpuArch::a100();
        let total = a.slot_bw_gbs() * a.sm_slots() as f64;
        assert!((total - a.hbm_bw_gbs).abs() < 1e-6);
    }
}
