//! Speculative-decoding cost model: speedup as a function of acceptance
//! rate and draft length.
//!
//! One verify pass scores `k` drafts (plus the pending token) with a
//! multi-query lean pass that streams the cached context **once**; the
//! sequential baseline streams it once *per committed token*. With
//! per-draft acceptance rate `α`, a pass commits
//! `E(α, k) = 1 + α + α² + ... + α^k` tokens in expectation, so the
//! modeled whole-decode speedup is `E × t_step / t_verify` — approaching
//! `E` itself as the context grows and the verify pass stays
//! memory-bound (its extra query rows ride the same KV stream). This is
//! the modeled counterpart of the measured numbers from
//! `leanattn bench --spec`.

use crate::partition::multi_query::{MultiQueryProblem, MultiQuerySeq};
use crate::partition::plan::{DecodeProblem, Strategy};

use super::arch::GpuArch;
use super::cascade::simulate_cascade;
use super::cost::kv_stream_bytes;
use super::schedule::simulate;

/// Shape of one modeled speculative decode step.
#[derive(Clone, Copy, Debug)]
pub struct SpecDecodeCase {
    pub heads: usize,
    pub head_dim: usize,
    /// Cached context tokens at verify time.
    pub ctx: usize,
    /// Draft tokens per pass (the verify block has `k + 1` query rows).
    pub k: usize,
    /// Per-draft acceptance probability `α` in `[0, 1]`.
    pub acceptance: f64,
}

/// Modeled outcome of one speculative step vs its sequential baseline.
#[derive(Clone, Debug)]
pub struct SpecSimResult {
    /// Expected tokens committed per verify pass, `E(α, k)`.
    pub tokens_per_pass: f64,
    /// Modeled latency of the multi-query verify pass (us).
    pub verify_us: f64,
    /// Modeled latency of committing the same expected tokens
    /// sequentially (`E` single-query steps, us).
    pub sequential_us: f64,
    /// Modeled HBM KV bytes of the verify pass (context streamed once).
    pub verify_kv_bytes: f64,
    /// Modeled HBM KV bytes of the sequential baseline (context streamed
    /// once per committed token).
    pub sequential_kv_bytes: f64,
}

impl SpecSimResult {
    /// Whole-decode speedup of speculative over sequential decoding.
    pub fn speedup(&self) -> f64 {
        if self.verify_us <= 0.0 {
            return 1.0;
        }
        self.sequential_us / self.verify_us
    }

    /// Fraction of sequential KV traffic the verify pass avoids.
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.sequential_kv_bytes <= 0.0 {
            return 0.0;
        }
        1.0 - self.verify_kv_bytes / self.sequential_kv_bytes
    }
}

/// `E(α, k) = Σ_{i=0..k} α^i` — expected tokens per verify pass: the
/// accepted draft prefix is geometric, truncated at `k`, plus the one
/// correction/bonus token every pass commits.
pub fn expected_tokens_per_pass(acceptance: f64, k: usize) -> f64 {
    let a = acceptance.clamp(0.0, 1.0);
    (0..=k).map(|i| a.powi(i as i32)).sum()
}

/// Model one speculative step on `arch`: the verify pass is the
/// multi-query expansion (one sequence, `k + 1` staggered-causal rows
/// sharing the context stream) through the cascade simulator; the
/// baseline is `E(α, k)` single-query stream-K steps.
pub fn simulate_spec_decode(case: &SpecDecodeCase, arch: &GpuArch) -> SpecSimResult {
    assert!(case.k >= 1 && case.ctx >= 1);
    let e = expected_tokens_per_pass(case.acceptance, case.k);

    let mq = MultiQueryProblem::new(
        case.heads,
        case.head_dim,
        vec![MultiQuerySeq { base_len: case.ctx, q_len: case.k + 1 }],
        Vec::new(),
    )
    .expect("spec-decode problems are valid by construction");
    let cp = mq.expand().tile_aligned();
    let vr = simulate_cascade(&cp, arch);

    let step = DecodeProblem::uniform(1, case.heads, case.ctx + 1, case.head_dim);
    let sr = simulate(&step, Strategy::StreamK, arch);
    let step_bytes = kv_stream_bytes(step.total_tiles(), step.tile, case.head_dim);

    SpecSimResult {
        tokens_per_pass: e,
        verify_us: vr.latency_us,
        sequential_us: sr.latency_us * e,
        verify_kv_bytes: vr.kv_bytes,
        sequential_kv_bytes: step_bytes * e,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(ctx: usize, k: usize, acceptance: f64) -> SpecDecodeCase {
        SpecDecodeCase { heads: 8, head_dim: 64, ctx, k, acceptance }
    }

    #[test]
    fn expected_tokens_formula() {
        assert!((expected_tokens_per_pass(0.0, 4) - 1.0).abs() < 1e-12);
        assert!((expected_tokens_per_pass(1.0, 4) - 5.0).abs() < 1e-12);
        assert!((expected_tokens_per_pass(0.5, 2) - 1.75).abs() < 1e-12);
        assert!((expected_tokens_per_pass(0.8, 4) - 3.3616).abs() < 1e-9);
    }

    #[test]
    fn high_acceptance_long_context_speeds_up() {
        let r = simulate_spec_decode(&case(65_536, 4, 0.9), &GpuArch::a100());
        assert!(
            r.speedup() > 1.5,
            "k=4 at 90% acceptance must beat sequential ({:.2}x)",
            r.speedup()
        );
        assert!(r.verify_kv_bytes < r.sequential_kv_bytes);
        assert!(r.bytes_saved_fraction() > 0.5);
    }

    #[test]
    fn zero_acceptance_never_beats_sequential_but_stays_close() {
        // α = 0: one token per pass, and the verify pass costs about one
        // decode step (its extra rows ride the same KV stream).
        let r = simulate_spec_decode(&case(65_536, 4, 0.0), &GpuArch::a100());
        assert!((r.tokens_per_pass - 1.0).abs() < 1e-12);
        assert!(r.speedup() <= 1.05, "no free lunch at α=0 ({:.2}x)", r.speedup());
        assert!(r.speedup() > 0.5, "memory-bound verify stays cheap");
    }

    #[test]
    fn speedup_grows_with_acceptance() {
        let arch = GpuArch::a100();
        let mut prev = 0.0;
        for a in [0.0, 0.5, 0.8, 0.95] {
            let s = simulate_spec_decode(&case(32_768, 4, a), &arch).speedup();
            assert!(s > prev, "α={a}: speedup {s} <= {prev}");
            prev = s;
        }
    }

    #[test]
    fn verify_bytes_track_one_context_walk() {
        // The verify pass streams ~one context regardless of k.
        let arch = GpuArch::a100();
        let r2 = simulate_spec_decode(&case(65_536, 2, 0.8), &arch);
        let r8 = simulate_spec_decode(&case(65_536, 8, 0.8), &arch);
        let ratio = r8.verify_kv_bytes / r2.verify_kv_bytes;
        assert!(ratio < 1.1, "verify bytes must not scale with k ({ratio:.3})");
    }
}
