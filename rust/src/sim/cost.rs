//! LeanTile cost model.
//!
//! Decode attention is memory-bandwidth-bound (arithmetic intensity ≈ 1
//! FLOP/byte — paper §I, [37]): each LeanTile iteration streams `tile × d`
//! K rows and V rows from HBM exactly once, does two skinny matmuls, and
//! keeps everything else resident. The per-tile latency is therefore
//! `bytes_moved / slot_bandwidth`, with a small fixed issue overhead, and
//! an MXU/ALU floor that only matters for tiny tiles.

use super::arch::GpuArch;
use crate::obs::attrib::WorkAccounting;
use crate::partition::plan::Strategy;

/// Per-strategy per-tile execution cost on a given architecture.
#[derive(Clone, Copy, Debug)]
pub struct TileCost {
    /// Latency for one full LeanTile iteration, microseconds.
    pub tile_us: f64,
    /// Fixed per-segment setup (Q tile load, index math), microseconds.
    pub segment_setup_us: f64,
}

/// KV element size in bytes (fp16/bf16 storage, as the paper's FP16→32).
pub const KV_BYTES: f64 = 2.0;

impl TileCost {
    /// Cost of a LeanTile of `tile` tokens × `head_dim` for `strategy`.
    pub fn new(arch: &GpuArch, tile: usize, head_dim: usize, strategy: Strategy) -> Self {
        Self::with_queries(arch, tile, head_dim, strategy, 1)
    }

    /// Like [`TileCost::new`], but the tile's K/V stream serves `queries`
    /// query rows at once (a cascade shared-prefix segment). Memory
    /// traffic is unchanged — that is the whole point of sharing — but
    /// the compute floor scales with the query count (the GEMV has become
    /// a skinny GEMM), so very wide groups eventually go compute-bound.
    pub fn with_queries(
        arch: &GpuArch,
        tile: usize,
        head_dim: usize,
        strategy: Strategy,
        queries: usize,
    ) -> Self {
        assert!(queries >= 1);
        // K + V streamed once per iteration, shared by all query rows.
        let bytes = 2.0 * tile as f64 * head_dim as f64 * KV_BYTES;
        let gather = match strategy {
            Strategy::PagedFixedSplit { .. } => arch.paged_gather_penalty,
            _ => 1.0,
        };
        // slot_bw is GB/s == bytes/ns; convert to us.
        let mem_us = bytes * gather / (arch.slot_bw_gbs() * 1e3);
        // Compute floor: 4 * tile * d FLOPs per tile *per query row* at
        // ~1/slots of peak.
        let flops = 4.0 * tile as f64 * head_dim as f64 * queries as f64;
        let slot_flops_per_us =
            arch.peak_tflops * 1e6 / arch.sm_slots() as f64;
        let mxu_us = flops / slot_flops_per_us;
        TileCost {
            tile_us: mem_us.max(mxu_us),
            segment_setup_us: 0.15,
        }
    }
}

/// Modeled HBM bytes to stream `tiles` LeanTiles of K+V once.
pub fn kv_stream_bytes(tiles: u64, tile: usize, head_dim: usize) -> f64 {
    tiles as f64 * 2.0 * tile as f64 * head_dim as f64 * KV_BYTES
}

/// Calibrated execution-cost coefficients over the exact
/// [`WorkAccounting`] units: the linear model
/// `t_us = ns_per_byte · bytes + ns_per_flop · flops + tile_overhead_ns
/// · tiles` (all divided by 1000), fitted by `leanattn calibrate` from
/// traced host-executor runs ([`crate::obs::calibrate`]). Bytes are the
/// host executor's gathered-f32 bytes — not the fp16 device bytes
/// [`KV_BYTES`] models — so the two cost surfaces stay distinguishable.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostCoefficients {
    /// Nanoseconds per gathered KV byte (memory/gather term).
    pub ns_per_byte: f64,
    /// Nanoseconds per online-softmax flop (compute term).
    pub ns_per_flop: f64,
    /// Fixed nanoseconds per LeanTile visited (issue/setup overhead).
    pub tile_overhead_ns: f64,
}

impl CostCoefficients {
    /// Rough host-executor priors for when drift detection is enabled
    /// without a calibration file (`serve --drift-limit` alone). The
    /// [`crate::obs::drift::DriftDetector`] fits a scalar gain over its
    /// warmup window, so only the *ratios* between these terms matter;
    /// they mirror the shape `leanattn calibrate` typically fits on the
    /// host executor (gather-byte dominated, with a visible per-tile
    /// setup term).
    pub fn nominal() -> CostCoefficients {
        CostCoefficients {
            ns_per_byte: 0.05,
            ns_per_flop: 0.5,
            tile_overhead_ns: 200.0,
        }
    }

    /// Predicted execution time, in microseconds, for exact work `w`.
    pub fn predict_us(&self, w: &WorkAccounting) -> f64 {
        (self.ns_per_byte * w.gathered_kv_bytes as f64
            + self.ns_per_flop * w.softmax_flops as f64
            + self.tile_overhead_ns * w.tiles as f64)
            / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_tile_cost_order_of_magnitude() {
        // 256 x 64 fp16 tile = 64 KiB; at ~9.4 GB/s per slot ≈ 7 us.
        let c = TileCost::new(&GpuArch::a100(), 256, 64, Strategy::StreamK);
        assert!(c.tile_us > 1.0 && c.tile_us < 30.0, "tile_us = {}", c.tile_us);
    }

    #[test]
    fn memory_bound_not_compute_bound() {
        let arch = GpuArch::a100();
        let c = TileCost::new(&arch, 256, 64, Strategy::StreamK);
        let bytes = 2.0 * 256.0 * 64.0 * KV_BYTES;
        let mem_us = bytes / (arch.slot_bw_gbs() * 1e3);
        assert!((c.tile_us - mem_us).abs() / mem_us < 1e-9);
    }

    #[test]
    fn paged_gather_is_slower() {
        let arch = GpuArch::a100();
        let plain = TileCost::new(&arch, 256, 64, Strategy::FixedSplit { splits: 4 });
        let paged = TileCost::new(
            &arch,
            256,
            64,
            Strategy::PagedFixedSplit { splits: 4, page: 16 },
        );
        assert!(paged.tile_us > plain.tile_us);
    }

    #[test]
    fn shared_queries_keep_bytes_but_raise_compute_floor() {
        let arch = GpuArch::a100();
        let one = TileCost::new(&arch, 256, 64, Strategy::Cascade);
        let few = TileCost::with_queries(&arch, 256, 64, Strategy::Cascade, 8);
        // A handful of shared queries rides free on the same KV stream.
        assert_eq!(one.tile_us, few.tile_us, "memory-bound: same tile cost");
        // Enough queries and the tile goes compute-bound.
        let many = TileCost::with_queries(&arch, 256, 64, Strategy::Cascade, 100_000);
        assert!(many.tile_us > one.tile_us);
    }

    #[test]
    fn kv_stream_bytes_counts_k_and_v_once() {
        // 1 tile of 256 x 64 fp16: 2 tensors * 256 * 64 * 2 bytes = 64 KiB.
        assert_eq!(kv_stream_bytes(1, 256, 64), 65536.0);
        assert_eq!(kv_stream_bytes(10, 256, 64), 655360.0);
    }

    #[test]
    fn coefficients_price_exact_work_linearly() {
        let c = CostCoefficients {
            ns_per_byte: 0.5,
            ns_per_flop: 0.01,
            tile_overhead_ns: 100.0,
        };
        let w = WorkAccounting {
            tiles: 10,
            gathered_kv_bytes: 2000,
            softmax_flops: 50_000,
            rescale_folds: 20,
        };
        // 0.5*2000 + 0.01*50000 + 100*10 = 1000 + 500 + 1000 ns = 2.5 us.
        assert!((c.predict_us(&w) - 2.5).abs() < 1e-12);
        assert_eq!(CostCoefficients::default().predict_us(&w), 0.0);
        // The nominal priors must price real work at a positive time,
        // or `serve --drift-limit` without a calibration file would
        // silently observe nothing.
        assert!(CostCoefficients::nominal().predict_us(&w) > 0.0);
    }

    #[test]
    fn cost_scales_with_tile_and_dim() {
        let arch = GpuArch::a100();
        let small = TileCost::new(&arch, 128, 64, Strategy::StreamK);
        let big = TileCost::new(&arch, 256, 64, Strategy::StreamK);
        assert!((big.tile_us / small.tile_us - 2.0).abs() < 0.01);
        let wide = TileCost::new(&arch, 128, 128, Strategy::StreamK);
        assert!((wide.tile_us / small.tile_us - 2.0).abs() < 0.01);
    }
}
