//! Fork-group (parallel sampling) decode cost model.
//!
//! Best-of-n and beam search fork one sequence into `siblings` that
//! share their entire history up to the fork point and then grow short
//! divergent suffixes. On the modeled GPU this is a decode-step sequence
//! whose cascade structure *changes every step*: at step `t` each
//! sibling's context is `history + t + 1` tokens of which `history` are
//! shared, so the flat plan re-streams the shared history once per
//! sibling per step while the cascade plan streams it once per step.
//! [`simulate_fork_decode`] accumulates both over a whole decode phase —
//! the modeled counterpart of the measured numbers from `leanattn bench
//! --sampling`.

use crate::partition::cascade::{CascadeProblem, PrefixGroup};
use crate::partition::plan::Strategy;

use super::arch::GpuArch;
use super::cascade::simulate_cascade;
use super::schedule::simulate;

/// Shape of one fork-group decode phase.
#[derive(Clone, Copy, Debug)]
pub struct ForkDecodeCase {
    pub heads: usize,
    pub head_dim: usize,
    /// Sequences in the fork family (parent + siblings).
    pub siblings: usize,
    /// Tokens shared by the whole family at the fork point.
    pub history: usize,
    /// Decode steps to model (each sibling grows one token per step).
    pub decode_steps: usize,
}

/// Accumulated model outcome over the decode phase.
#[derive(Clone, Debug, Default)]
pub struct ForkDecodeResult {
    /// Modeled HBM KV bytes of the flat plan (history re-streamed per
    /// sibling per step), summed over steps.
    pub flat_kv_bytes: f64,
    /// Modeled HBM KV bytes of the cascade plan (history streamed once
    /// per step for the family), summed over steps.
    pub cascade_kv_bytes: f64,
    /// Summed flat stream-K attention latency (us).
    pub flat_us: f64,
    /// Summed cascade attention latency (us).
    pub cascade_us: f64,
    /// Steps modeled.
    pub steps: usize,
}

impl ForkDecodeResult {
    /// Fraction of the flat plan's KV traffic the cascade plan avoids.
    pub fn bytes_saved_fraction(&self) -> f64 {
        if self.flat_kv_bytes <= 0.0 {
            return 0.0;
        }
        1.0 - self.cascade_kv_bytes / self.flat_kv_bytes
    }

    /// Whole-decode speedup of the cascade plan over flat stream-K.
    pub fn speedup(&self) -> f64 {
        if self.cascade_us <= 0.0 {
            return 1.0;
        }
        self.flat_us / self.cascade_us
    }
}

/// Model a fork family's whole decode phase on `arch`: one cascade
/// problem per step (shared history as the prefix group, per-sibling
/// suffix growing by one token each step) against the flat stream-K
/// plan over the same contexts.
pub fn simulate_fork_decode(case: &ForkDecodeCase, arch: &GpuArch) -> ForkDecodeResult {
    assert!(case.siblings >= 1 && case.decode_steps >= 1);
    let mut res = ForkDecodeResult::default();
    for t in 0..case.decode_steps {
        let ctx = (case.history + t + 1) as u32;
        let groups = if case.siblings >= 2 && case.history >= 1 {
            vec![PrefixGroup {
                prefix_len: case.history as u32,
                members: (0..case.siblings as u32).collect(),
            }]
        } else {
            Vec::new()
        };
        let p = CascadeProblem::new(
            case.heads,
            vec![ctx; case.siblings],
            case.head_dim,
            groups,
        )
        .expect("fork-decode problems are valid by construction")
        .tile_aligned();
        let r = simulate_cascade(&p, arch);
        let flat = simulate(&p.baseline_problem(), Strategy::StreamK, arch);
        res.flat_kv_bytes += r.baseline_kv_bytes;
        res.cascade_kv_bytes += r.kv_bytes;
        res.flat_us += flat.latency_us;
        res.cascade_us += r.latency_us;
        res.steps += 1;
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(siblings: usize, history: usize, steps: usize) -> ForkDecodeCase {
        ForkDecodeCase {
            heads: 8,
            head_dim: 64,
            siblings,
            history,
            decode_steps: steps,
        }
    }

    #[test]
    fn fork_groups_stream_strictly_fewer_bytes() {
        let r = simulate_fork_decode(&case(4, 16_384, 8), &GpuArch::a100());
        assert!(
            r.cascade_kv_bytes < r.flat_kv_bytes,
            "cascade {} vs flat {}",
            r.cascade_kv_bytes,
            r.flat_kv_bytes
        );
        assert!(r.bytes_saved_fraction() > 0.5, "long shared history dominates");
        assert_eq!(r.steps, 8);
    }

    #[test]
    fn solo_decode_matches_flat() {
        let r = simulate_fork_decode(&case(1, 16_384, 4), &GpuArch::a100());
        assert!((r.cascade_kv_bytes - r.flat_kv_bytes).abs() < 1e-6);
        assert!((r.bytes_saved_fraction()).abs() < 1e-9);
    }

    #[test]
    fn savings_grow_with_family_size() {
        let arch = GpuArch::a100();
        let mut prev = 0.0;
        for n in [2usize, 4, 8] {
            let r = simulate_fork_decode(&case(n, 32_768, 4), &arch);
            assert!(
                r.bytes_saved_fraction() > prev,
                "n={n}: {} <= {prev}",
                r.bytes_saved_fraction()
            );
            prev = r.bytes_saved_fraction();
        }
        // Asymptote: 1 - 1/n as the history dominates the suffix.
        assert!((prev - 0.875).abs() < 0.05, "n=8 saved {prev}");
    }

    #[test]
    fn short_history_below_one_tile_degenerates_to_flat() {
        // tile for d=64 exceeds a 3-token history: tile_aligned prunes
        // the group and the model reports zero savings, not negative.
        let r = simulate_fork_decode(&case(4, 3, 2), &GpuArch::a100());
        assert!((r.cascade_kv_bytes - r.flat_kv_bytes).abs() < 1e-6);
        assert!(r.speedup() > 0.5 && r.speedup() < 1.5);
    }
}
