//! Transformer model configurations.
//!
//! The paper evaluates attention shapes drawn from Phi-3 Medium (40 heads,
//! d=128), LLaMA-2-7B, Mistral-7B and OPT; the e2e artifacts serve the
//! `tiny`/`small` configs built by `python/compile/aot.py`. Parameter
//! counts here drive the Fig 2 / Fig 12 timeshare model.

/// Decoder-only transformer hyper-parameters (inference view).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// KV heads (GQA); == n_heads when no grouping.
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    /// MLP weight matrices per layer (2 = up/down, 3 = gated SwiGLU).
    pub mlp_mults: usize,
}

impl ModelConfig {
    /// Phi-3 Medium 14B: the paper's end-to-end model (Figs 2, 12).
    pub fn phi3_medium() -> Self {
        ModelConfig {
            name: "phi3-medium",
            vocab: 32_064,
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            n_kv_heads: 10,
            head_dim: 128,
            d_ff: 17_920,
            mlp_mults: 3,
        }
    }

    /// LLaMA-2-7B (Fig 11's head-dim-128 family).
    pub fn llama2_7b() -> Self {
        ModelConfig {
            name: "llama2-7b",
            vocab: 32_000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            d_ff: 11_008,
            mlp_mults: 3,
        }
    }

    /// Mistral-7B (Fig 11).
    pub fn mistral_7b() -> Self {
        ModelConfig {
            name: "mistral-7b",
            vocab: 32_000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 14_336,
            mlp_mults: 3,
        }
    }

    /// OPT-30B-like (the paper's HuggingFace e2e vehicle; d=128 variant).
    pub fn opt_30b() -> Self {
        ModelConfig {
            name: "opt-30b",
            vocab: 50_272,
            d_model: 7168,
            n_layers: 48,
            n_heads: 56,
            n_kv_heads: 56,
            head_dim: 128,
            d_ff: 28_672,
            mlp_mults: 2,
        }
    }

    /// LLaMA-2-70B-style GQA config: 64 query heads over 8 KV heads
    /// (group size 8) — the canonical served GQA shape.
    pub fn llama70b_gqa() -> Self {
        ModelConfig {
            name: "llama70b-gqa",
            vocab: 32_000,
            d_model: 8192,
            n_layers: 80,
            n_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            d_ff: 28_672,
            mlp_mults: 3,
        }
    }

    /// Multi-query attention (Shazeer 2019): all query heads share a
    /// single KV head — the h/h_kv extreme of the GQA spectrum.
    pub fn mqa() -> Self {
        ModelConfig {
            name: "mqa",
            vocab: 32_000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 1,
            head_dim: 128,
            d_ff: 11_008,
            mlp_mults: 3,
        }
    }

    /// Look a named preset up (CLI `--model-preset`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "phi3-medium" => Some(Self::phi3_medium()),
            "llama2-7b" => Some(Self::llama2_7b()),
            "mistral-7b" => Some(Self::mistral_7b()),
            "opt-30b" => Some(Self::opt_30b()),
            "llama70b-gqa" => Some(Self::llama70b_gqa()),
            "mqa" => Some(Self::mqa()),
            _ => None,
        }
    }

    /// Names accepted by [`ModelConfig::by_name`].
    pub const PRESET_NAMES: &'static [&'static str] = &[
        "phi3-medium",
        "llama2-7b",
        "mistral-7b",
        "opt-30b",
        "llama70b-gqa",
        "mqa",
    ];

    /// A d=64 model with many heads (the operation-level benchmark shape:
    /// 56 heads × d 64 — Figs 3, 13).
    pub fn bench_d64(heads: usize) -> Self {
        ModelConfig {
            name: "bench-d64",
            vocab: 32_000,
            d_model: heads * 64,
            n_layers: 32,
            n_heads: heads,
            n_kv_heads: heads,
            head_dim: 64,
            d_ff: heads * 64 * 4,
            mlp_mults: 2,
        }
    }

    /// Query heads per KV head (1 when ungrouped, `n_heads` for MQA).
    pub fn group_size(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Check the GQA shape invariant: `n_kv_heads` divides `n_heads`.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_kv_heads >= 1, "{}: n_kv_heads must be >= 1", self.name);
        anyhow::ensure!(
            self.n_heads % self.n_kv_heads == 0,
            "{}: n_heads {} not divisible by n_kv_heads {}",
            self.name,
            self.n_heads,
            self.n_kv_heads
        );
        Ok(())
    }

    /// Total parameter count (tied LM head).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let attn = d * (self.n_heads * self.head_dim) as u64 // Wq
            + 2 * d * (self.n_kv_heads * self.head_dim) as u64 // Wk, Wv
            + (self.n_heads * self.head_dim) as u64 * d; // Wo
        let mlp = self.mlp_mults as u64 * d * self.d_ff as u64;
        let per_layer = attn + mlp + 2 * d; // + layernorms
        self.vocab as u64 * d + self.n_layers as u64 * per_layer + d
    }

    /// Bytes of KV cache per token (fp16 storage).
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * (self.n_layers * self.n_kv_heads * self.head_dim) as u64 * 2
    }

    /// FLOPs for one decode-step pass through the linear layers
    /// (2 × params, weight-streaming matvec).
    pub fn decode_linear_flops(&self) -> u64 {
        2 * self.param_count()
    }

    /// FLOPs to prefill a prompt of `p` tokens (2·P·params + attention).
    pub fn prefill_flops(&self, p: u64) -> u64 {
        2 * p * self.param_count()
            + 2 * 2 * p * p * (self.n_layers * self.n_heads * self.head_dim) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phi3_medium_is_14b_class() {
        let c = ModelConfig::phi3_medium();
        let b = c.param_count() as f64 / 1e9;
        assert!((12.0..16.0).contains(&b), "phi3 params {b}B");
        assert_eq!(c.n_heads, 40); // paper: "Phi-3 Medium (40 heads)"
        assert_eq!(c.head_dim, 128);
    }

    #[test]
    fn llama2_7b_class() {
        let b = ModelConfig::llama2_7b().param_count() as f64 / 1e9;
        assert!((6.0..8.0).contains(&b), "llama2 params {b}B");
    }

    #[test]
    fn mistral_gqa_smaller_kv() {
        let m = ModelConfig::mistral_7b();
        let l = ModelConfig::llama2_7b();
        assert!(m.kv_bytes_per_token() < l.kv_bytes_per_token());
    }

    #[test]
    fn kv_bytes_formula() {
        let c = ModelConfig::llama2_7b();
        // 32 layers * 32 heads * 128 dim * 2 (K+V) * 2 bytes = 524288
        assert_eq!(c.kv_bytes_per_token(), 524_288);
    }

    #[test]
    fn every_preset_validates_and_resolves_by_name() {
        for name in ModelConfig::PRESET_NAMES {
            let c = ModelConfig::by_name(name).expect("preset resolves");
            assert_eq!(&c.name, name);
            c.validate().unwrap();
        }
        assert!(ModelConfig::by_name("no-such-model").is_none());
    }

    #[test]
    fn gqa_presets_shrink_kv_by_the_group_size() {
        let g = ModelConfig::llama70b_gqa();
        assert_eq!((g.n_heads, g.n_kv_heads, g.group_size()), (64, 8, 8));
        let m = ModelConfig::mqa();
        assert_eq!(m.group_size(), m.n_heads);
        // KV bytes scale with n_kv_heads, not n_heads.
        let dense = ModelConfig { n_kv_heads: m.n_heads, ..m.clone() };
        assert_eq!(dense.kv_bytes_per_token(), m.kv_bytes_per_token() * m.n_heads as u64);
    }

    #[test]
    fn validate_rejects_non_dividing_kv_heads() {
        let bad = ModelConfig { n_kv_heads: 3, ..ModelConfig::llama2_7b() };
        assert!(bad.validate().is_err());
        let zero = ModelConfig { n_kv_heads: 0, ..ModelConfig::llama2_7b() };
        assert!(zero.validate().is_err());
    }
}
