//! Model zoo: transformer configurations used across the evaluation
//! (paper §V-VI) plus the tiny configs the PJRT artifacts serve.

pub mod config;

pub use config::ModelConfig;
