//! The softmax re-scaling reduction operator (§IV-A).
//!
//! A partial attention result for one query row is `(O~, m, l)`:
//! un-scaled output `O~ ∈ R^d`, running rowmax `m`, running rowsum `l`.
//! The operator
//!
//! ```text
//! m'  = max(m_x, m_y)
//! l'  = e^{m_x - m'} l_x + e^{m_y - m'} l_y
//! O~' = e^{m_x - m'} O~_x + e^{m_y - m'} O~_y
//! ```
//!
//! is **associative** (proved in the paper and property-tested in
//! `rust/tests/associativity.rs`), has the identity element
//! `(0, NEG_INF, 0)`, and is commutative in value — which is what lets
//! LeanAttention split a head's context into *unequal* blocks, compute the
//! partials anywhere, and reduce them in whatever order the host CTAs see
//! them (Alg 2 lines 24-39).
//!
//! This is the L3 hot path: the engine reduces every stream-K partial
//! here, so `rescale_row` is written to be allocation-free and
//! auto-vectorizable.

/// Finite stand-in for -inf, shared with the Pallas kernels (`ref.NEG_INF`).
/// `exp(NEG_INF - m)` underflows to exactly 0.0 for any realistic `m`.
pub const NEG_INF: f32 = -1.0e30;

/// Per-row softmax statistics carried alongside the un-scaled output.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowStats {
    /// Running row maximum of attention scores.
    pub m: f32,
    /// Running row sum of `exp(score - m)`.
    pub l: f32,
}

impl RowStats {
    /// The reduction identity: contributes zero weight.
    pub const IDENTITY: RowStats = RowStats { m: NEG_INF, l: 0.0 };

    /// Log-sum-exp of the scores this row has seen (FA2's `L`).
    pub fn lse(&self) -> f32 {
        if self.l == 0.0 {
            NEG_INF
        } else {
            self.m + self.l.ln()
        }
    }
}

/// Fold `(o_y, y)` into the accumulator `(o_acc, acc)` in place.
///
/// Equivalent to `f(acc, y)` in §IV-A. `o_acc` and `o_y` are the d-element
/// un-scaled outputs of one query row.
#[inline]
pub fn rescale_row(o_acc: &mut [f32], acc: &mut RowStats, o_y: &[f32], y: RowStats) {
    debug_assert_eq!(o_acc.len(), o_y.len());
    let m_new = acc.m.max(y.m);
    // exp(NEG_INF - NEG_INF) would be NaN; both-identity means stay identity.
    if m_new <= NEG_INF {
        return;
    }
    let ax = (acc.m - m_new).exp();
    let ay = (y.m - m_new).exp();
    acc.l = ax * acc.l + ay * y.l;
    acc.m = m_new;
    // The common fast path in a stream-K reduce is ax == 1.0 (accumulator
    // already holds the max); skip the accumulator scaling then.
    if ax == 1.0 {
        for (a, &b) in o_acc.iter_mut().zip(o_y) {
            *a += ay * b;
        }
    } else {
        for (a, &b) in o_acc.iter_mut().zip(o_y) {
            *a = ax * *a + ay * b;
        }
    }
}

/// Group-broadcast fold (the cascade execution path): partial row `j` of
/// `(o_y, ys)` folds into accumulator row `targets[j]`.
///
/// A shared-prefix LeanTile is streamed **once** per prefix group but
/// produces one partial row per member query; this fold routes that one
/// partial batch into every member's accumulator in a single call, so the
/// executor never has to re-shuffle partials into per-output order.
/// Duplicate targets are legal (several partials of one output row in the
/// same batch) — folds apply in order, and the operator is associative
/// and commutative in value, so grouping does not change the result.
pub fn rescale_group_broadcast(
    o_acc: &mut [f32],
    acc: &mut [RowStats],
    d: usize,
    o_y: &[f32],
    ys: &[RowStats],
    targets: &[usize],
) {
    debug_assert_eq!(o_acc.len(), acc.len() * d);
    debug_assert_eq!(o_y.len(), ys.len() * d);
    debug_assert_eq!(ys.len(), targets.len());
    for (j, &gi) in targets.iter().enumerate() {
        rescale_row(
            &mut o_acc[gi * d..(gi + 1) * d],
            &mut acc[gi],
            &o_y[j * d..(j + 1) * d],
            ys[j],
        );
    }
}

/// Final normalization `O = diag(l)^-1 O~` for `g` rows of width `d`
/// (Alg 2 line 38). Rows with `l == 0` (identity — nothing attended) are
/// left as zeros rather than NaN.
pub fn finalize_rows(o: &mut [f32], stats: &[RowStats], d: usize) {
    debug_assert_eq!(o.len(), stats.len() * d);
    for (row, st) in o.chunks_mut(d).zip(stats) {
        if st.l != 0.0 {
            let inv = 1.0 / st.l;
            for x in row {
                *x *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_allclose, prop_check};

    fn reduce_pair(
        a: (&[f32], RowStats),
        b: (&[f32], RowStats),
    ) -> (Vec<f32>, RowStats) {
        let mut o = a.0.to_vec();
        let mut st = a.1;
        rescale_row(&mut o, &mut st, b.0, b.1);
        (o, st)
    }

    #[test]
    fn identity_element_is_neutral() {
        let o = vec![1.0f32, -2.0, 3.0];
        let st = RowStats { m: 0.7, l: 2.0 };
        let (o2, st2) = reduce_pair((&o, st), (&[0.0, 0.0, 0.0], RowStats::IDENTITY));
        assert_eq!(o2, o);
        assert_eq!(st2, st);
        // identity on the left too
        let (o3, st3) = reduce_pair((&[0.0, 0.0, 0.0], RowStats::IDENTITY), (&o, st));
        assert_allclose(&o3, &o, 1e-7, 1e-7, "left identity");
        assert!((st3.m - st.m).abs() < 1e-7 && (st3.l - st.l).abs() < 1e-7);
    }

    #[test]
    fn both_identity_stays_identity() {
        let (o, st) = reduce_pair(
            (&[0.0, 0.0], RowStats::IDENTITY),
            (&[0.0, 0.0], RowStats::IDENTITY),
        );
        assert_eq!(o, vec![0.0, 0.0]);
        assert_eq!(st, RowStats::IDENTITY);
        assert!(st.lse() <= NEG_INF);
    }

    #[test]
    fn commutative_in_value() {
        prop_check("rescale commutes", 200, |rng| {
            let d = 8;
            let ox: Vec<f32> = rng.normal_vec(d);
            let oy: Vec<f32> = rng.normal_vec(d);
            let sx = RowStats { m: rng.normal() as f32, l: rng.f32() + 0.1 };
            let sy = RowStats { m: rng.normal() as f32, l: rng.f32() + 0.1 };
            let (axy, stxy) = reduce_pair((&ox, sx), (&oy, sy));
            let (ayx, styx) = reduce_pair((&oy, sy), (&ox, sx));
            for (a, b) in axy.iter().zip(&ayx) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("o mismatch {a} {b}"));
                }
            }
            if (stxy.l - styx.l).abs() > 1e-5 * stxy.l.abs().max(1.0) {
                return Err("l mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn associative() {
        prop_check("rescale associates", 300, |rng| {
            let d = 4;
            let parts: Vec<(Vec<f32>, RowStats)> = (0..3)
                .map(|_| {
                    (
                        rng.normal_vec(d),
                        RowStats {
                            m: (rng.normal() * 3.0) as f32,
                            l: rng.f32() * 4.0 + 0.01,
                        },
                    )
                })
                .collect();
            let (xy, st_xy) = reduce_pair(
                (&parts[0].0, parts[0].1),
                (&parts[1].0, parts[1].1),
            );
            let (xy_z, st_xyz) = reduce_pair((&xy, st_xy), (&parts[2].0, parts[2].1));
            let (yz, st_yz) = reduce_pair(
                (&parts[1].0, parts[1].1),
                (&parts[2].0, parts[2].1),
            );
            let (x_yz, st_x_yz) = reduce_pair((&parts[0].0, parts[0].1), (&yz, st_yz));
            // Compare *finalized* outputs (the theorem's statement).
            for ((a, b)) in xy_z
                .iter()
                .map(|v| v / st_xyz.l)
                .zip(x_yz.iter().map(|v| v / st_x_yz.l))
            {
                let (a, b): (f32, f32) = (a, b);
                if (a - b).abs() > 1e-5 {
                    return Err(format!("assoc mismatch {a} {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn group_broadcast_matches_sequential_folds() {
        prop_check("group broadcast == per-row folds", 100, |rng| {
            let d = 4;
            let rows = rng.urange(1, 6);
            let outs = rng.urange(1, 4);
            let o_y: Vec<f32> = rng.normal_vec(rows * d);
            let ys: Vec<RowStats> = (0..rows)
                .map(|_| RowStats {
                    m: (rng.normal() * 2.0) as f32,
                    l: rng.f32() * 3.0 + 0.05,
                })
                .collect();
            // Duplicate targets allowed: several partials fold into one row.
            let targets: Vec<usize> = (0..rows).map(|_| rng.urange(0, outs)).collect();

            let mut o_a = vec![0.0f32; outs * d];
            let mut st_a = vec![RowStats::IDENTITY; outs];
            rescale_group_broadcast(&mut o_a, &mut st_a, d, &o_y, &ys, &targets);

            let mut o_b = vec![0.0f32; outs * d];
            let mut st_b = vec![RowStats::IDENTITY; outs];
            for (j, &gi) in targets.iter().enumerate() {
                rescale_row(
                    &mut o_b[gi * d..(gi + 1) * d],
                    &mut st_b[gi],
                    &o_y[j * d..(j + 1) * d],
                    ys[j],
                );
            }
            for (a, b) in o_a.iter().zip(&o_b) {
                if (a - b).abs() > 1e-6 {
                    return Err(format!("o mismatch {a} {b}"));
                }
            }
            for (a, b) in st_a.iter().zip(&st_b) {
                if (a.l - b.l).abs() > 1e-6 {
                    return Err("l mismatch".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn group_broadcast_identity_rows_are_neutral() {
        let d = 2;
        let mut o = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut st = vec![RowStats { m: 0.5, l: 1.0 }; 2];
        let before = (o.clone(), st.clone());
        rescale_group_broadcast(
            &mut o,
            &mut st,
            d,
            &[0.0, 0.0, 0.0, 0.0],
            &[RowStats::IDENTITY; 2],
            &[1, 0],
        );
        assert_eq!(o, before.0);
        assert_eq!(st, before.1);
    }

    #[test]
    fn finalize_skips_zero_rows() {
        let mut o = vec![2.0, 4.0, 0.0, 0.0];
        let stats = vec![RowStats { m: 0.0, l: 2.0 }, RowStats::IDENTITY];
        finalize_rows(&mut o, &stats, 2);
        assert_eq!(o, vec![1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn lse_matches_naive() {
        let st = RowStats { m: 3.0, l: 2.0 };
        assert!((st.lse() - (3.0 + 2.0f32.ln())).abs() < 1e-6);
    }
}
