//! Batched partial-attention container: `G` query rows × head_dim `d`,
//! each with its `(m, l)` statistics. This is the unit the engine moves
//! between the PJRT partial-attention artifact and the Rust reduction.

use super::rescale::{finalize_rows, rescale_group_broadcast, rescale_row, RowStats};

/// `G` un-scaled partial outputs with their softmax statistics.
#[derive(Clone, Debug)]
pub struct Partials {
    pub g: usize,
    pub d: usize,
    /// Row-major `[g, d]` un-scaled outputs.
    pub o: Vec<f32>,
    pub stats: Vec<RowStats>,
}

impl Partials {
    /// The reduction identity for `g` rows of width `d`.
    pub fn identity(g: usize, d: usize) -> Partials {
        Partials {
            g,
            d,
            o: vec![0.0; g * d],
            stats: vec![RowStats::IDENTITY; g],
        }
    }

    /// Build from flat `(o, m, l)` buffers as produced by the PJRT partial
    /// artifact (`o: [g, d]`, `m/l: [g, 1]` flattened).
    pub fn from_flat(g: usize, d: usize, o: Vec<f32>, m: &[f32], l: &[f32]) -> Partials {
        assert_eq!(o.len(), g * d);
        assert_eq!(m.len(), g);
        assert_eq!(l.len(), g);
        let stats = m
            .iter()
            .zip(l)
            .map(|(&m, &l)| RowStats { m, l })
            .collect();
        Partials { g, d, o, stats }
    }

    /// Fold `other` into `self` row-by-row (the §IV-A operator, batched).
    pub fn reduce_from(&mut self, other: &Partials) {
        assert_eq!(self.g, other.g);
        assert_eq!(self.d, other.d);
        let d = self.d;
        for gi in 0..self.g {
            rescale_row(
                &mut self.o[gi * d..(gi + 1) * d],
                &mut self.stats[gi],
                &other.o[gi * d..(gi + 1) * d],
                other.stats[gi],
            );
        }
    }

    /// Fold only the rows in `rows` (engine path: a peer CTA contributed to
    /// a subset of output tiles).
    pub fn reduce_rows_from(&mut self, other: &Partials, rows: &[usize]) {
        let d = self.d;
        for &gi in rows {
            rescale_row(
                &mut self.o[gi * d..(gi + 1) * d],
                &mut self.stats[gi],
                &other.o[gi * d..(gi + 1) * d],
                other.stats[gi],
            );
        }
    }

    /// Group-broadcast fold (cascade path): `other`'s row `j` folds into
    /// this accumulator's row `targets[j]`. A shared-prefix partial batch
    /// carries one row per member query of its group; this routes the
    /// whole batch into the members' accumulators in one call. Duplicate
    /// targets are legal and fold in order.
    pub fn fold_group_broadcast(&mut self, other: &Partials, targets: &[usize]) {
        assert_eq!(self.d, other.d);
        assert_eq!(other.g, targets.len());
        rescale_group_broadcast(
            &mut self.o,
            &mut self.stats,
            self.d,
            &other.o,
            &other.stats,
            targets,
        );
    }

    /// Normalize into the exact attention output (consumes the partials).
    pub fn finalize(mut self) -> Vec<f32> {
        finalize_rows(&mut self.o, &self.stats, self.d);
        self.o
    }

    /// Log-sum-exp per row (FA2's `L` output).
    pub fn lse(&self) -> Vec<f32> {
        self.stats.iter().map(|s| s.lse()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::assert_allclose;

    fn random_partials(rng: &mut Rng, g: usize, d: usize) -> Partials {
        Partials {
            g,
            d,
            o: rng.normal_vec(g * d),
            stats: (0..g)
                .map(|_| RowStats {
                    m: (rng.normal() * 2.0) as f32,
                    l: rng.f32() * 3.0 + 0.05,
                })
                .collect(),
        }
    }

    #[test]
    fn identity_then_reduce_equals_copy() {
        let mut rng = Rng::new(5);
        let p = random_partials(&mut rng, 4, 8);
        let mut acc = Partials::identity(4, 8);
        acc.reduce_from(&p);
        assert_allclose(&acc.o, &p.o, 1e-6, 1e-6, "o");
        for (a, b) in acc.stats.iter().zip(&p.stats) {
            assert!((a.m - b.m).abs() < 1e-6 && (a.l - b.l).abs() < 1e-6);
        }
    }

    #[test]
    fn from_flat_round_trip() {
        let o = vec![1.0, 2.0, 3.0, 4.0];
        let p = Partials::from_flat(2, 2, o.clone(), &[0.1, 0.2], &[1.0, 2.0]);
        assert_eq!(p.o, o);
        assert_eq!(p.stats[1], RowStats { m: 0.2, l: 2.0 });
    }

    #[test]
    fn reduce_rows_only_touches_selected() {
        let mut rng = Rng::new(6);
        let a = random_partials(&mut rng, 3, 4);
        let b = random_partials(&mut rng, 3, 4);
        let mut sel = a.clone();
        sel.reduce_rows_from(&b, &[1]);
        // row 0 and 2 unchanged
        assert_eq!(&sel.o[0..4], &a.o[0..4]);
        assert_eq!(&sel.o[8..12], &a.o[8..12]);
        // row 1 matches full reduce
        let mut full = a.clone();
        full.reduce_from(&b);
        assert_allclose(&sel.o[4..8], &full.o[4..8], 1e-6, 1e-6, "row1");
    }

    #[test]
    fn fold_group_broadcast_routes_rows_to_targets() {
        let mut rng = Rng::new(7);
        // Partial batch of 3 rows scattering into accumulator rows 2, 0, 2.
        let part = random_partials(&mut rng, 3, 4);
        let targets = [2usize, 0, 2];
        let mut acc = Partials::identity(3, 4);
        acc.fold_group_broadcast(&part, &targets);

        // Row-by-row reference with plain rescale folds.
        let mut want = Partials::identity(3, 4);
        for (j, &gi) in targets.iter().enumerate() {
            let mut one = Partials::identity(3, 4);
            one.o[gi * 4..(gi + 1) * 4].copy_from_slice(&part.o[j * 4..(j + 1) * 4]);
            one.stats[gi] = part.stats[j];
            want.reduce_from(&one);
        }
        assert_allclose(&acc.o, &want.o, 1e-6, 1e-6, "scattered o");
        for (a, b) in acc.stats.iter().zip(&want.stats) {
            assert!((a.l - b.l).abs() < 1e-6 && (a.m - b.m).abs() < 1e-6);
        }
        // Row 1 received nothing and stays identity.
        assert_eq!(acc.stats[1], RowStats::IDENTITY);
    }

    #[test]
    fn finalize_normalizes() {
        let p = Partials::from_flat(1, 2, vec![2.0, 6.0], &[0.0], &[2.0]);
        assert_eq!(p.finalize(), vec![1.0, 3.0]);
    }
}
