//! Scalar exact-attention oracle on the host. Mirrors
//! `python/compile/kernels/ref.py` so the Rust side can validate both the
//! PJRT artifacts and the partition plans without crossing the FFI.
//!
//! All math in f64 accumulation over f32 storage — the tolerance anchor
//! for everything else in the repo.

use super::partials::Partials;
use super::rescale::{RowStats, NEG_INF};

/// Exact decode attention.
///
/// * `q: [g, d]`, `k/v: [g, n, d]` row-major, `lens[g]` valid context per
///   group. Returns `[g, d]`.
pub fn attention_host(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: usize,
    n: usize,
    d: usize,
    lens: &[u32],
) -> Vec<f32> {
    let p = partial_attention_host(q, k, v, g, n, d, lens, 0);
    p.finalize()
}

/// Un-scaled partial attention over rows `[0, n)` of a KV slice, where
/// only the first `lens[g] - start` rows (clamped) are valid — i.e. the
/// slice begins at absolute context offset `start`.
#[allow(clippy::too_many_arguments)]
pub fn partial_attention_host(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: usize,
    n: usize,
    d: usize,
    lens: &[u32],
    start: usize,
) -> Partials {
    assert_eq!(q.len(), g * d, "q shape");
    assert_eq!(k.len(), g * n * d, "k shape");
    assert_eq!(v.len(), g * n * d, "v shape");
    assert_eq!(lens.len(), g, "lens shape");
    let scale = 1.0 / (d as f64).sqrt();

    let mut out = Partials::identity(g, d);
    let mut scores = vec![0.0f64; n];
    for gi in 0..g {
        let valid = (lens[gi] as usize).saturating_sub(start).min(n);
        if valid == 0 {
            continue;
        }
        let qrow = &q[gi * d..(gi + 1) * d];
        let kmat = &k[gi * n * d..(gi + 1) * n * d];
        let vmat = &v[gi * n * d..(gi + 1) * n * d];

        let mut m = f64::from(NEG_INF);
        for (t, s) in scores.iter_mut().enumerate().take(valid) {
            let krow = &kmat[t * d..(t + 1) * d];
            let dot: f64 = qrow
                .iter()
                .zip(krow)
                .map(|(&a, &b)| f64::from(a) * f64::from(b))
                .sum();
            *s = dot * scale;
            m = m.max(*s);
        }

        let mut l = 0.0f64;
        let mut acc = vec![0.0f64; d];
        for t in 0..valid {
            let w = (scores[t] - m).exp();
            l += w;
            let vrow = &vmat[t * d..(t + 1) * d];
            for (a, &b) in acc.iter_mut().zip(vrow) {
                *a += w * f64::from(b);
            }
        }
        for (o, a) in out.o[gi * d..(gi + 1) * d].iter_mut().zip(&acc) {
            *o = *a as f32;
        }
        out.stats[gi] = RowStats { m: m as f32, l: l as f32 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testing::{assert_allclose, prop_check};

    #[test]
    fn single_token_returns_v0() {
        let mut rng = Rng::new(1);
        let (g, n, d) = (3, 8, 4);
        let q = rng.normal_vec(g * d);
        let k = rng.normal_vec(g * n * d);
        let v = rng.normal_vec(g * n * d);
        let lens = vec![1u32; g];
        let o = attention_host(&q, &k, &v, g, n, d, &lens);
        for gi in 0..g {
            assert_allclose(
                &o[gi * d..(gi + 1) * d],
                &v[gi * n * d..gi * n * d + d],
                1e-6,
                1e-6,
                "v0",
            );
        }
    }

    #[test]
    fn uniform_scores_average_values() {
        // identical K rows -> softmax uniform -> output = mean of V rows
        let (g, n, d) = (1, 4, 2);
        let q = vec![1.0, 0.0];
        let k = vec![1.0, 0.0].repeat(n);
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let o = attention_host(&q, &k, &v, g, n, d, &[4]);
        assert_allclose(&o, &[4.0, 5.0], 1e-6, 1e-6, "mean");
    }

    #[test]
    fn partials_cover_context_equals_full() {
        prop_check("split partials reduce to full", 50, |rng| {
            let g = rng.urange(1, 4);
            let n = rng.urange(4, 64);
            let d = *rng.choose(&[4usize, 8, 16]);
            let q = rng.normal_vec(g * d);
            let k = rng.normal_vec(g * n * d);
            let v = rng.normal_vec(g * n * d);
            let lens: Vec<u32> = (0..g).map(|_| rng.range(1, n as u64 + 1) as u32).collect();

            let full = attention_host(&q, &k, &v, g, n, d, &lens);

            // random split point
            let cut = rng.urange(1, n);
            let slice = |m: &[f32], lo: usize, hi: usize| -> Vec<f32> {
                let mut out = Vec::with_capacity(g * (hi - lo) * d);
                for gi in 0..g {
                    out.extend_from_slice(&m[gi * n * d + lo * d..gi * n * d + hi * d]);
                }
                out
            };
            let k1 = slice(&k, 0, cut);
            let v1 = slice(&v, 0, cut);
            let k2 = slice(&k, cut, n);
            let v2 = slice(&v, cut, n);
            let mut p1 = partial_attention_host(&q, &k1, &v1, g, cut, d, &lens, 0);
            let p2 = partial_attention_host(&q, &k2, &v2, g, n - cut, d, &lens, cut);
            p1.reduce_from(&p2);
            let got = p1.finalize();
            for (a, b) in got.iter().zip(&full) {
                if (a - b).abs() > 1e-4 {
                    return Err(format!("mismatch {a} vs {b} (cut {cut})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn start_offset_masks_prefix_lens() {
        // A slice whose start is beyond the length contributes identity.
        let mut rng = Rng::new(3);
        let (g, n, d) = (2, 8, 4);
        let q = rng.normal_vec(g * d);
        let k = rng.normal_vec(g * n * d);
        let v = rng.normal_vec(g * n * d);
        let p = partial_attention_host(&q, &k, &v, g, n, d, &[4, 2], 6);
        assert_eq!(p.stats[0], RowStats::IDENTITY);
        assert_eq!(p.stats[1], RowStats::IDENTITY);
        assert!(p.o.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn extreme_scores_stay_finite() {
        let mut rng = Rng::new(4);
        let (g, n, d) = (2, 16, 8);
        let q: Vec<f32> = rng.normal_vec(g * d).iter().map(|x| x * 100.0).collect();
        let k = rng.normal_vec(g * n * d);
        let v = rng.normal_vec(g * n * d);
        let o = attention_host(&q, &k, &v, g, n, d, &[16, 16]);
        assert!(o.iter().all(|x| x.is_finite()));
    }
}
