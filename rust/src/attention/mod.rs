//! Exact attention math on the host: the softmax re-scaling reduction
//! operator (§IV-A of the paper), a scalar reference attention used as the
//! Rust-side oracle, and a host executor that runs a [`crate::partition`]
//! plan end-to-end on real numbers (each simulated CTA computes its
//! partials; host CTAs reduce) — the numerical proof that any partitioning
//! the planners emit computes *exact* attention.

pub mod partials;
pub mod reference;
pub mod rescale;

pub use partials::Partials;
pub use reference::{attention_host, partial_attention_host};
pub use rescale::{finalize_rows, rescale_group_broadcast, rescale_row, RowStats, NEG_INF};
