//! PJRT runtime: loads the HLO-text artifacts `python/compile/aot.py`
//! emits and executes them on the CPU PJRT client. Python never runs on
//! this path — the Rust binary is self-contained once `make artifacts`
//! has produced `artifacts/`.
//!
//! * [`client`]   — PJRT client + executable wrappers.
//! * [`tensor`]   — host tensors ⇄ XLA literals.
//! * [`artifacts`]— `manifest.json` parsing and bucket lookup.
//! * [`weights`]  — flat f32 weight-blob loading.
//! * [`attention_exec`] — decode attention over the kernel artifacts,
//!   including the stream-K partial path reduced in Rust.
//! * [`model_exec`] — transformer prefill/decode step execution.

pub mod artifacts;
pub mod attention_exec;
pub mod client;
pub mod model_exec;
pub mod tensor;
pub mod weights;
pub mod xla_shim;

pub use artifacts::Manifest;
pub use attention_exec::AttentionExecutor;
pub use client::{Executable, Runtime};
pub use model_exec::{ModelRuntime, VerifyOut};
pub use tensor::HostTensor;
