//! Host-side stand-in for the `xla-rs` PJRT bindings.
//!
//! The offline crate cache has no `xla` crate (it needs the native
//! `xla_extension` toolchain), so the runtime modules import this shim as
//! `xla` instead (`use super::xla_shim as xla`). The shim keeps the exact
//! API surface the runtime uses:
//!
//! * **Literals are fully functional** — they are plain host containers,
//!   so every tensor⇄literal conversion path (and its tests) runs for
//!   real.
//! * **Compilation/execution is unavailable** — `from_text_file`,
//!   `compile` and `execute` return a clear error. Callers never reach
//!   them without AOT artifacts on disk, and every artifact-dependent
//!   test self-skips when `artifacts/manifest.json` is absent.
//!
//! Swapping the real bindings back in is a one-line change per importer.

use std::path::Path;

/// Error type for shim operations (carried into `anyhow` by callers).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the PJRT runtime (the `xla` crate is not in the \
         offline cargo cache; this build uses the host shim)"
    ))
}

/// Element types the artifacts use (subset of XLA's primitive types).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    F16,
    Bf16,
    F32,
    F64,
    Tuple,
}

/// Literal payload storage (public only because the [`Element`] trait
/// names it; not part of the intended API surface).
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

/// A host literal: dims + typed flat data (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Dense array shape of a literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

/// Elements storable in a [`Literal`].
pub trait Element: Copy {
    fn store(data: &[Self]) -> Data;
    fn extract(lit: &Literal) -> Result<Vec<Self>, Error>;
}

impl Element for f32 {
    fn store(data: &[Self]) -> Data {
        Data::F32(data.to_vec())
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>, Error> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            Data::S32(_) => Err(Error("literal holds s32, expected f32".into())),
        }
    }
}

impl Element for i32 {
    fn store(data: &[Self]) -> Data {
        Data::S32(data.to_vec())
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>, Error> {
        match &lit.data {
            Data::S32(v) => Ok(v.clone()),
            Data::F32(_) => Err(Error("literal holds f32, expected s32".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::store(data) }
    }

    /// Reinterpret the flat data under new dims (element count must match).
    pub fn reshape(self, dims: &[i64]) -> Result<Literal, Error> {
        let have = match &self.data {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
        };
        let want: i64 = dims.iter().product();
        if want as usize != have {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elems) from {have} elems"
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        let ty = match &self.data {
            Data::F32(_) => PrimitiveType::F32,
            Data::S32(_) => PrimitiveType::S32,
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        T::extract(self)
    }

    /// Decompose a tuple literal. The shim never produces tuples (they
    /// only come back from executions, which the shim cannot run).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("tuple literal decomposition"))
    }
}

/// PJRT client stand-in: the host *is* the device, so construction and
/// inventory work; compilation does not.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compiling an XLA computation"))
    }
}

/// Parsed HLO module stand-in.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!(
            "parsing HLO text {}",
            path.as_ref().display()
        )))
    }
}

/// XLA computation stand-in.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer stand-in (never constructed by the shim).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("fetching a device buffer"))
    }
}

/// Loaded executable stand-in (never constructed by the shim).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executing a compiled computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_round_trip_on_host() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0])
            .reshape(&[2, 2])
            .unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.primitive_type(), PrimitiveType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn execution_paths_error_clearly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu");
        assert!(client.device_count() >= 1);
        let err = HloModuleProto::from_text_file("x.hlo").err().unwrap();
        assert!(err.to_string().contains("PJRT"));
        assert!(PjRtLoadedExecutable.execute::<&Literal>(&[]).is_err());
    }
}
