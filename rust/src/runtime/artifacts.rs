//! `artifacts/manifest.json` parsing and bucket lookup.
//!
//! The AOT pipeline compiles attention kernels for a grid of
//! `(g = batch×heads, head_dim, ctx)` buckets; at runtime a problem is
//! padded up to the smallest bucket that fits (lengths are masked inside
//! the kernel, so padding is exact).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One attention artifact bucket.
#[derive(Clone, Debug)]
pub struct AttentionArtifact {
    pub kind: AttentionKind,
    pub g: usize,
    pub d: usize,
    pub ctx: usize,
    pub tile: usize,
    pub file: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttentionKind {
    /// Full decode attention: `(q, k, v, lens) -> (o, lse)`.
    Full,
    /// Un-scaled partials: `(q, k, v, valid) -> (o~, m, l)`.
    Partial,
}

/// One transformer model's artifact set.
#[derive(Clone, Debug)]
pub struct ModelArtifact {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// KV heads (GQA); divides `n_heads`. Manifests from before the
    /// grouped-KV plane default to `n_heads` (one KV head per query
    /// head), which keeps old artifact sets bit-identical.
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub ctx_bucket: usize,
    pub prefill_bucket: usize,
    pub batch: usize,
    pub param_count: usize,
    /// Rotary base of the model's position embedding (the sparse decode
    /// path re-rotates fresh K rows from compacted to true positions
    /// with it). Manifests from before this field default to the python
    /// layer's `ModelConfig.rope_base` default.
    pub rope_base: f64,
    pub decode_file: String,
    pub prefill_file: String,
    /// Multi-token verify step for speculative decoding (absent in
    /// artifact sets built before the spec subsystem).
    pub verify_file: Option<String>,
    /// Tokens the verify step scores per sequence and call (pending
    /// token + drafts); 0 when no verify artifact exists.
    pub spec_bucket: usize,
    pub weights_file: String,
    /// Flat parameter order: (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
}

/// Parsed manifest with artifact lookups.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub attention: Vec<AttentionArtifact>,
    pub models: BTreeMap<String, ModelArtifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).context("parse manifest.json")?;
        if json.usize_at("version") != 1 {
            bail!("unsupported manifest version");
        }

        let mut attention = Vec::new();
        for e in json.at("attention").as_arr().context("attention array")? {
            let kind = match e.str_at("kind") {
                "full" => AttentionKind::Full,
                "partial" => AttentionKind::Partial,
                k => bail!("unknown attention kind {k}"),
            };
            attention.push(AttentionArtifact {
                kind,
                g: e.usize_at("g"),
                d: e.usize_at("d"),
                ctx: e.usize_at("ctx"),
                tile: e.usize_at("tile"),
                file: e.str_at("file").to_string(),
            });
        }

        let mut models = BTreeMap::new();
        if let Some(obj) = json.at("models").as_obj() {
            for (name, m) in obj {
                let cfg = m.at("config");
                let params = m
                    .at("params")
                    .as_arr()
                    .context("params")?
                    .iter()
                    .map(|p| {
                        let shape = p
                            .at("shape")
                            .as_arr()
                            .unwrap()
                            .iter()
                            .map(|d| d.as_usize().unwrap())
                            .collect();
                        (p.str_at("name").to_string(), shape)
                    })
                    .collect();
                models.insert(
                    name.clone(),
                    ModelArtifact {
                        name: name.clone(),
                        vocab: cfg.usize_at("vocab"),
                        d_model: cfg.usize_at("d_model"),
                        n_layers: cfg.usize_at("n_layers"),
                        n_heads: cfg.usize_at("n_heads"),
                        n_kv_heads: cfg
                            .get("n_kv_heads")
                            .and_then(|v| v.as_u64())
                            .map(|v| v as usize)
                            .unwrap_or_else(|| cfg.usize_at("n_heads")),
                        head_dim: cfg.usize_at("head_dim"),
                        d_ff: cfg.usize_at("d_ff"),
                        ctx_bucket: cfg.usize_at("ctx_bucket"),
                        prefill_bucket: cfg.usize_at("prefill_bucket"),
                        batch: cfg.usize_at("batch"),
                        param_count: cfg.usize_at("param_count"),
                        rope_base: cfg
                            .get("rope_base")
                            .and_then(|v| v.as_f64())
                            .unwrap_or(10_000.0),
                        decode_file: m.at("decode").str_at("file").to_string(),
                        prefill_file: m.at("prefill").str_at("file").to_string(),
                        verify_file: m
                            .get("verify")
                            .map(|v| v.str_at("file").to_string()),
                        spec_bucket: m
                            .get("verify")
                            .map(|v| v.usize_at("spec_bucket"))
                            .unwrap_or(0),
                        weights_file: m.str_at("weights").to_string(),
                        params,
                    },
                );
            }
        }

        Ok(Manifest { dir, attention, models })
    }

    /// Default artifact directory: `$LEANATTN_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("LEANATTN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Cheapest bucket with `g >= g_need`, `ctx >= ctx_need`, exact `d`.
    /// "Cheapest" = least padded work (`g × ctx`), tie-broken by shape —
    /// kernel cost is proportional to the padded area, so lexicographic
    /// `(g, ctx)` would happily pick a 16×4096 bucket for a 16×256 task
    /// (16× the work) over a 32×256 one.
    pub fn find_attention(
        &self,
        kind: AttentionKind,
        d: usize,
        g_need: usize,
        ctx_need: usize,
    ) -> Option<&AttentionArtifact> {
        self.attention
            .iter()
            .filter(|a| {
                a.kind == kind && a.d == d && a.g >= g_need && a.ctx >= ctx_need
            })
            .min_by_key(|a| (a.g * a.ctx, a.g, a.ctx))
    }

    /// Largest partial-attention bucket for dimension `d` (the chunking
    /// target when a problem exceeds every bucket).
    pub fn largest_partial(&self, d: usize) -> Option<&AttentionArtifact> {
        self.attention
            .iter()
            .filter(|a| a.kind == AttentionKind::Partial && a.d == d)
            .max_by_key(|a| (a.ctx, a.g))
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifact> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        manifest_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(manifest_dir()).unwrap();
        assert!(!m.attention.is_empty());
        assert!(m.models.contains_key("tiny"));
        let tiny = m.model("tiny").unwrap();
        assert!(!tiny.params.is_empty());
        assert!(m.path_of(&tiny.weights_file).exists());
    }

    #[test]
    fn bucket_selection_prefers_smallest_fit() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(manifest_dir()).unwrap();
        let a = m
            .find_attention(AttentionKind::Full, 64, 5, 200)
            .expect("bucket for g=5 ctx=200");
        assert!(a.g >= 5 && a.ctx >= 200);
        // smallest: no other bucket strictly smaller fits
        for other in &m.attention {
            if other.kind == AttentionKind::Full
                && other.d == 64
                && other.g >= 5
                && other.ctx >= 200
            {
                assert!((a.g, a.ctx) <= (other.g, other.ctx));
            }
        }
    }

    #[test]
    fn oversized_requests_fail_gracefully() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(manifest_dir()).unwrap();
        assert!(m.find_attention(AttentionKind::Full, 64, 10_000, 256).is_none());
        assert!(m.largest_partial(64).is_some());
    }
}
