//! PJRT client + compiled-executable wrappers.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! ≥ 0.5 serialized protos carry 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::tensor::HostTensor;
use super::xla_shim as xla;

/// A PJRT device connection (CPU in this environment).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Connect to the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it for this device.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable { exe, path: path.to_path_buf() })
    }
}

/// A compiled computation ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// device output is a tuple we decompose into per-output tensors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_literals(&lits.iter().collect::<Vec<_>>())
    }

    /// Execute with pre-built literals (lets callers cache e.g. weights).
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<HostTensor>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.path.display()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = lit.to_tuple().context("decompose result tuple")?;
        parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()
    }
}

#[cfg(test)]
mod tests {
    //! Runtime tests live in `rust/tests/pjrt_attention.rs` (they need the
    //! AOT artifacts); here we only check client construction, which must
    //! work without artifacts.
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().expect("cpu client");
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert!(rt.device_count() >= 1);
    }
}
