//! Flat f32 weight-blob loading (written by `python/compile/aot.py` in
//! `param_order`; little-endian f32, concatenated).

use anyhow::{ensure, Context, Result};

use super::artifacts::{Manifest, ModelArtifact};
use super::tensor::HostTensor;

/// Load a model's weights as host tensors in parameter order.
pub fn load_weights(manifest: &Manifest, model: &ModelArtifact) -> Result<Vec<HostTensor>> {
    let path = manifest.path_of(&model.weights_file);
    let blob = std::fs::read(&path)
        .with_context(|| format!("read weights blob {}", path.display()))?;
    let expect: usize = model
        .params
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum::<usize>()
        * 4;
    ensure!(
        blob.len() == expect,
        "weights blob {} bytes, manifest says {expect}",
        blob.len()
    );

    let mut out = Vec::with_capacity(model.params.len());
    let mut off = 0usize;
    for (name, shape) in &model.params {
        let n: usize = shape.iter().product();
        let bytes = &blob[off..off + n * 4];
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        ensure!(
            data.iter().all(|x| x.is_finite()),
            "non-finite weight in {name}"
        );
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        out.push(HostTensor::f32(&dims, data));
        off += n * 4;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn loads_tiny_weights() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let tiny = m.model("tiny").unwrap();
        let ws = load_weights(&m, tiny).unwrap();
        assert_eq!(ws.len(), tiny.params.len());
        let total: usize = ws.iter().map(|w| w.len()).sum();
        assert_eq!(total, tiny.param_count);
        // embed is first and non-trivial
        assert_eq!(ws[0].dims.len(), 2);
        assert!(ws[0].as_f32().unwrap().iter().any(|&x| x != 0.0));
    }
}
