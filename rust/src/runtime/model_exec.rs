//! Transformer prefill/decode step execution over the model artifacts.
//!
//! Weights are uploaded once as XLA literals and reused across every call
//! — the only per-step traffic is tokens, positions and the KV cache
//! views the coordinator materializes.

use std::rc::Rc;

use anyhow::{ensure, Context, Result};

use super::artifacts::{Manifest, ModelArtifact};
use super::client::{Executable, Runtime};
use super::tensor::HostTensor;
use super::weights::load_weights;
use super::xla_shim as xla;

/// Output of one decode step.
pub struct DecodeOut {
    /// `[b, vocab]` next-token logits.
    pub logits: Vec<f32>,
    /// `[l, b, h, dh]` fresh K rows for the token just consumed.
    pub new_k: Vec<f32>,
    /// `[l, b, h, dh]` fresh V rows.
    pub new_v: Vec<f32>,
}

/// Output of a prefill call.
pub struct PrefillOut {
    /// `[b, vocab]` logits of each sequence's last real token.
    pub logits: Vec<f32>,
    /// `[l, b, h, p, dh]` prompt K cache.
    pub k: Vec<f32>,
    /// `[l, b, h, p, dh]` prompt V cache.
    pub v: Vec<f32>,
}

/// Output of a multi-token verify call (speculative decoding).
pub struct VerifyOut {
    /// `[b, s, vocab]` logits after every draft-block position — the
    /// per-position logit surfacing speculative verification (and true
    /// frontier beam search) needs.
    pub logits: Vec<f32>,
    /// `[l, b, h, s, dh]` K rows of the draft-block tokens.
    pub new_k: Vec<f32>,
    /// `[l, b, h, s, dh]` V rows.
    pub new_v: Vec<f32>,
}

/// A loaded model: compiled steps + uploaded weights.
pub struct ModelRuntime {
    pub art: ModelArtifact,
    decode: Executable,
    prefill: Executable,
    /// Multi-token verify step, when the artifact set provides one.
    verify: Option<Executable>,
    weight_literals: Vec<xla::Literal>,
}

impl ModelRuntime {
    pub fn load(runtime: &Rc<Runtime>, manifest: &Manifest, name: &str) -> Result<ModelRuntime> {
        let art = manifest.model(name)?.clone();
        let decode = runtime
            .load_hlo(manifest.path_of(&art.decode_file))
            .context("compile decode step")?;
        let prefill = runtime
            .load_hlo(manifest.path_of(&art.prefill_file))
            .context("compile prefill step")?;
        let verify = match &art.verify_file {
            Some(f) => Some(
                runtime
                    .load_hlo(manifest.path_of(f))
                    .context("compile verify step")?,
            ),
            None => None,
        };
        let weights = load_weights(manifest, &art)?;
        let weight_literals = weights
            .iter()
            .map(|w| w.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelRuntime { art, decode, prefill, verify, weight_literals })
    }

    /// Whether this model can run multi-token verify passes (a verify
    /// artifact with a usable draft block exists).
    pub fn has_verify(&self) -> bool {
        self.verify.is_some() && self.art.spec_bucket >= 2
    }

    /// KV cache element count: the cache is stored at **kv-head**
    /// granularity (`[l, b, h_kv, ctx_bucket, dh]`); `n_kv_heads`
    /// defaults to `n_heads` for pre-GQA artifact sets.
    pub fn cache_elems(&self) -> usize {
        self.art.n_layers * self.art.batch * self.art.n_kv_heads * self.art.ctx_bucket
            * self.art.head_dim
    }

    /// One decode step.
    ///
    /// * `tokens[b]` — current token per sequence.
    /// * `k_cache/v_cache` — `[l, b, h_kv, ctx_bucket, dh]` materialized caches
    ///   holding each sequence's first `positions[b]` tokens.
    /// * `positions[b]` — number of cached tokens (the fresh token's index).
    pub fn decode(
        &self,
        tokens: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        positions: &[i32],
    ) -> Result<DecodeOut> {
        let b = self.art.batch;
        ensure!(tokens.len() == b, "tokens len");
        ensure!(positions.len() == b, "positions len");
        ensure!(k_cache.len() == self.cache_elems(), "k_cache size");
        ensure!(v_cache.len() == self.cache_elems(), "v_cache size");
        for &p in positions {
            ensure!(
                (p as usize) < self.art.ctx_bucket,
                "position {p} exceeds ctx bucket {}",
                self.art.ctx_bucket
            );
        }

        let (l, h, c, dh) = (
            self.art.n_layers as i64,
            self.art.n_kv_heads as i64,
            self.art.ctx_bucket as i64,
            self.art.head_dim as i64,
        );
        // Literals straight from the borrowed buffers: one copy into XLA
        // instead of Vec-clone + copy (perf log in EXPERIMENTS.md §Perf).
        let dyn_literals = [
            HostTensor::literal_i32(&[b as i64], tokens)?,
            HostTensor::literal_f32(&[l, b as i64, h, c, dh], k_cache)?,
            HostTensor::literal_f32(&[l, b as i64, h, c, dh], v_cache)?,
            HostTensor::literal_i32(&[b as i64], positions)?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.weight_literals.iter().collect();
        inputs.extend(dyn_literals.iter());

        let out = self.decode.run_literals(&inputs)?;
        ensure!(out.len() == 3, "decode outputs");
        let mut it = out.into_iter();
        Ok(DecodeOut {
            logits: it.next().unwrap().into_f32()?,
            new_k: it.next().unwrap().into_f32()?,
            new_v: it.next().unwrap().into_f32()?,
        })
    }

    /// One multi-token verify pass (speculative decoding).
    ///
    /// * `tokens[b * s]` — per sequence, `s = spec_bucket` draft-block
    ///   tokens: the pending token followed by `s - 1` drafted tokens
    ///   (row-major `[b, s]`).
    /// * `k_cache/v_cache` — the same `[l, b, h_kv, ctx_bucket, dh]` views
    ///   [`Self::decode`] consumes, holding `positions[b]` tokens.
    /// * `positions[b]` — cached tokens (the block's first index).
    ///
    /// The artifact computes causal attention of all `s` block tokens
    /// against cache + block in one pass — the k-query lean pass that
    /// turns k memory-bound decode steps into one context walk — and
    /// returns per-position logits plus the block's K/V rows.
    pub fn verify(
        &self,
        tokens: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        positions: &[i32],
    ) -> Result<VerifyOut> {
        let exe = self
            .verify
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("model {:?} has no verify artifact", self.art.name))?;
        let b = self.art.batch;
        let s = self.art.spec_bucket;
        ensure!(s >= 1, "spec bucket unset");
        ensure!(tokens.len() == b * s, "tokens len");
        ensure!(positions.len() == b, "positions len");
        ensure!(k_cache.len() == self.cache_elems(), "k_cache size");
        ensure!(v_cache.len() == self.cache_elems(), "v_cache size");
        for &p in positions {
            ensure!(
                p >= 0 && p as usize + s <= self.art.ctx_bucket,
                "position {p} leaves no room for a {s}-token draft block in ctx bucket {}",
                self.art.ctx_bucket
            );
        }

        let (l, h, c, dh) = (
            self.art.n_layers as i64,
            self.art.n_kv_heads as i64,
            self.art.ctx_bucket as i64,
            self.art.head_dim as i64,
        );
        let dyn_literals = [
            HostTensor::literal_i32(&[b as i64, s as i64], tokens)?,
            HostTensor::literal_f32(&[l, b as i64, h, c, dh], k_cache)?,
            HostTensor::literal_f32(&[l, b as i64, h, c, dh], v_cache)?,
            HostTensor::literal_i32(&[b as i64], positions)?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.weight_literals.iter().collect();
        inputs.extend(dyn_literals.iter());

        let out = exe.run_literals(&inputs)?;
        ensure!(out.len() == 3, "verify outputs");
        let mut it = out.into_iter();
        Ok(VerifyOut {
            logits: it.next().unwrap().into_f32()?,
            new_k: it.next().unwrap().into_f32()?,
            new_v: it.next().unwrap().into_f32()?,
        })
    }

    /// Prefill `tokens: [b, prefill_bucket]` (right-padded) with true
    /// `lengths[b]`.
    pub fn prefill(&self, tokens: &[i32], lengths: &[i32]) -> Result<PrefillOut> {
        let b = self.art.batch;
        let p = self.art.prefill_bucket;
        ensure!(tokens.len() == b * p, "tokens shape");
        ensure!(lengths.len() == b, "lengths shape");
        for &len in lengths {
            ensure!(len >= 1 && (len as usize) <= p, "prompt length {len}");
        }

        let dyn_literals = [
            HostTensor::literal_i32(&[b as i64, p as i64], tokens)?,
            HostTensor::literal_i32(&[b as i64], lengths)?,
        ];
        let mut inputs: Vec<&xla::Literal> = self.weight_literals.iter().collect();
        inputs.extend(dyn_literals.iter());

        let out = self.prefill.run_literals(&inputs)?;
        ensure!(out.len() == 3, "prefill outputs");
        let mut it = out.into_iter();
        Ok(PrefillOut {
            logits: it.next().unwrap().into_f32()?,
            k: it.next().unwrap().into_f32()?,
            v: it.next().unwrap().into_f32()?,
        })
    }
}

// Integration tests live in rust/tests/pjrt_model.rs (need artifacts).
