//! Host tensors and conversion to/from XLA literals.

use anyhow::{bail, Context, Result};

use super::xla_shim as xla;

/// Element storage for a host tensor (the two dtypes the artifacts use).
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense row-major host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<i64>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(dims: &[i64], data: Vec<f32>) -> HostTensor {
        assert_eq!(
            dims.iter().product::<i64>() as usize,
            data.len(),
            "f32 tensor shape/data mismatch"
        );
        HostTensor { dims: dims.to_vec(), data: TensorData::F32(data) }
    }

    pub fn i32(dims: &[i64], data: Vec<i32>) -> HostTensor {
        assert_eq!(
            dims.iter().product::<i64>() as usize,
            data.len(),
            "i32 tensor shape/data mismatch"
        );
        HostTensor { dims: dims.to_vec(), data: TensorData::I32(data) }
    }

    pub fn zeros_f32(dims: &[i64]) -> HostTensor {
        let n = dims.iter().product::<i64>() as usize;
        HostTensor::f32(dims, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Build an f32 literal straight from a borrowed slice (one copy into
    /// XLA, no intermediate Vec — the hot-path variant).
    pub fn literal_f32(dims: &[i64], data: &[f32]) -> Result<xla::Literal> {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        xla::Literal::vec1(data)
            .reshape(dims)
            .with_context(|| format!("reshape literal to {dims:?}"))
    }

    /// Build an i32 literal straight from a borrowed slice.
    pub fn literal_i32(dims: &[i64], data: &[i32]) -> Result<xla::Literal> {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        xla::Literal::vec1(data)
            .reshape(dims)
            .with_context(|| format!("reshape literal to {dims:?}"))
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        lit.reshape(&self.dims)
            .with_context(|| format!("reshape literal to {:?}", self.dims))
    }

    /// Read an XLA literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal array shape")?;
        let dims: Vec<i64> = shape.dims().to_vec();
        let data = match shape.primitive_type() {
            xla::PrimitiveType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::PrimitiveType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            ty => bail!("unsupported literal element type {ty:?}"),
        };
        Ok(HostTensor { dims, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn bad_shape_panics() {
        HostTensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn literal_round_trip() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);

        let ti = HostTensor::i32(&[3], vec![7, 8, 9]);
        let lit = ti.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), ti);
    }
}
