//! Decode attention over the PJRT kernel artifacts.
//!
//! Two execution paths:
//!
//! * [`AttentionExecutor::full`] — one fused kernel call per bucket
//!   (padding is exact because lengths are masked in-kernel).
//! * [`AttentionExecutor::lean`] — the LeanAttention path: a
//!   [`crate::partition::Plan`]'s CTA segments are chunked to the partial
//!   artifact's bucket, executed as batched partial-attention calls, and
//!   reduced in Rust with the softmax re-scaling operator (Alg 2 L24-39).
//!   Chunking a segment is exact for the same reason the paper's unequal
//!   splits are: the operator is associative.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::attention::{Partials, RowStats};
use crate::partition::plan::Plan;

use super::artifacts::{AttentionKind, Manifest};
use super::client::{Executable, Runtime};
use super::tensor::HostTensor;

/// Decode-attention inputs in the repo's flattened-group layout:
/// `q: [g, d]`, `k/v: [g, n, d]` row-major, `lens[g]`.
pub struct AttentionProblem<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub lens: &'a [u32],
    pub g: usize,
    pub n: usize,
    pub d: usize,
}

/// Compiles and caches attention artifacts; executes decode attention.
pub struct AttentionExecutor {
    runtime: Rc<Runtime>,
    manifest: Rc<Manifest>,
    cache: std::cell::RefCell<HashMap<String, Rc<Executable>>>,
}

impl AttentionExecutor {
    pub fn new(runtime: Rc<Runtime>, manifest: Rc<Manifest>) -> AttentionExecutor {
        AttentionExecutor {
            runtime,
            manifest,
            cache: Default::default(),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, file: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let exe = Rc::new(self.runtime.load_hlo(self.manifest.path_of(file))?);
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of distinct compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Exact decode attention through the fused `attn_full` artifact.
    /// Returns `(o: [g, d], lse: [g])`.
    pub fn full(&self, p: &AttentionProblem) -> Result<(Vec<f32>, Vec<f32>)> {
        let art = self
            .manifest
            .find_attention(AttentionKind::Full, p.d, p.g, p.n)
            .with_context(|| {
                format!("no full-attention bucket for g={} d={} ctx={}", p.g, p.d, p.n)
            })?;
        let exe = self.executable(&art.file)?;
        let (bg, bc, d) = (art.g, art.ctx, p.d);

        // Pad into the bucket (zeros + length masking make this exact).
        let mut q = vec![0.0f32; bg * d];
        let mut k = vec![0.0f32; bg * bc * d];
        let mut v = vec![0.0f32; bg * bc * d];
        let mut lens = vec![0i32; bg];
        for gi in 0..p.g {
            q[gi * d..(gi + 1) * d].copy_from_slice(&p.q[gi * d..(gi + 1) * d]);
            let src = gi * p.n * d;
            let dst = gi * bc * d;
            k[dst..dst + p.n * d].copy_from_slice(&p.k[src..src + p.n * d]);
            v[dst..dst + p.n * d].copy_from_slice(&p.v[src..src + p.n * d]);
            lens[gi] = p.lens[gi].min(p.n as u32) as i32;
        }

        let out = exe.run(&[
            HostTensor::f32(&[bg as i64, d as i64], q),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], k),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], v),
            HostTensor::i32(&[bg as i64], lens),
        ])?;
        let o_full = out[0].as_f32()?;
        let lse_full = out[1].as_f32()?;
        Ok((
            o_full[..p.g * d].to_vec(),
            lse_full[..p.g].to_vec(),
        ))
    }

    /// Un-scaled partial attention over a batch of same-width tasks via
    /// the `attn_partial` artifact. `q: [t, d]`, `kv: [t, w, d]`,
    /// `valid[t]`; returns `Partials` with `g = t`.
    fn partial_batch(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        valid: &[u32],
        t: usize,
        w: usize,
        d: usize,
    ) -> Result<Partials> {
        let art = self
            .manifest
            .find_attention(AttentionKind::Partial, d, t, w)
            .with_context(|| format!("no partial bucket for t={t} d={d} w={w}"))?;
        let exe = self.executable(&art.file)?;
        let (bg, bc) = (art.g, art.ctx);

        let mut qb = vec![0.0f32; bg * d];
        let mut kb = vec![0.0f32; bg * bc * d];
        let mut vb = vec![0.0f32; bg * bc * d];
        let mut validb = vec![0i32; bg];
        qb[..t * d].copy_from_slice(q);
        for ti in 0..t {
            let src = ti * w * d;
            let dst = ti * bc * d;
            kb[dst..dst + w * d].copy_from_slice(&k[src..src + w * d]);
            vb[dst..dst + w * d].copy_from_slice(&v[src..src + w * d]);
            validb[ti] = valid[ti].min(w as u32) as i32;
        }

        let out = exe.run(&[
            HostTensor::f32(&[bg as i64, d as i64], qb),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], kb),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], vb),
            HostTensor::i32(&[bg as i64], validb),
        ])?;
        let o = out[0].as_f32()?[..t * d].to_vec();
        let m = &out[1].as_f32()?[..t];
        let l = &out[2].as_f32()?[..t];
        Ok(Partials::from_flat(t, d, o, m, l))
    }

    /// LeanAttention: execute `plan`'s CTA segments through the partial
    /// artifact and reduce in Rust. Returns `(o: [g, d], lse: [g])`.
    pub fn lean(&self, p: &AttentionProblem, plan: &Plan) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = p.d;
        // Chunk tasks at LeanTile width and batch as many as the widest
        // available group bucket allows: padded work then tracks real work
        // (perf note in EXPERIMENTS.md §Perf — the previous
        // largest-bucket choice cost ~100x on small problems).
        let chunk_w = plan.tile;
        let batch_t = self
            .manifest
            .attention
            .iter()
            .filter(|a| a.kind == AttentionKind::Partial && a.d == d && a.ctx >= chunk_w)
            .map(|a| a.g)
            .max()
            .with_context(|| format!("no partial bucket for d={d}"))?;

        // Roll plan segments out into bucket-width tasks.
        struct Task {
            group: usize,
            start: usize, // token offset in the group's context
            width: usize,
        }
        let mut tasks = Vec::new();
        for cta in &plan.ctas {
            for seg in &cta.segments {
                let gi = seg.group as usize;
                let ctx = (p.lens[gi] as usize).min(p.n);
                let mut tok = seg.tile_begin as usize * plan.tile;
                let seg_end =
                    ((seg.tile_begin + seg.tile_count) as usize * plan.tile).min(p.n);
                while tok < seg_end {
                    let width = chunk_w.min(seg_end - tok);
                    // Tasks fully beyond the valid length contribute the
                    // identity; skip them outright.
                    if tok < ctx {
                        tasks.push(Task { group: gi, start: tok, width });
                    }
                    tok += width;
                }
            }
        }

        // Execute tasks in batches of the artifact's group capacity.
        let mut acc = Partials::identity(p.g, d);
        let mut qb = Vec::new();
        let mut kb = Vec::new();
        let mut vb = Vec::new();
        let mut valid = Vec::new();
        let mut groups = Vec::new();
        for chunk in tasks.chunks(batch_t) {
            qb.clear();
            kb.clear();
            vb.clear();
            valid.clear();
            groups.clear();
            let w = chunk.iter().map(|t| t.width).max().unwrap();
            for task in chunk {
                let gi = task.group;
                qb.extend_from_slice(&p.q[gi * d..(gi + 1) * d]);
                let base = gi * p.n * d + task.start * d;
                kb.extend_from_slice(&p.k[base..base + task.width * d]);
                vb.extend_from_slice(&p.v[base..base + task.width * d]);
                // pad narrower tasks inside this batch to width w
                for _ in task.width..w {
                    kb.extend(std::iter::repeat(0.0).take(d));
                    vb.extend(std::iter::repeat(0.0).take(d));
                }
                let ctx = p.lens[gi] as usize;
                valid.push(ctx.saturating_sub(task.start).min(task.width) as u32);
                groups.push(gi);
            }
            let part =
                self.partial_batch(&qb, &kb, &vb, &valid, chunk.len(), w, d)?;
            // Fold each task's row into its group's accumulator.
            for (ti, &gi) in groups.iter().enumerate() {
                let row = &part.o[ti * d..(ti + 1) * d];
                let stats = part.stats[ti];
                fold_row(&mut acc, gi, row, stats);
            }
        }

        let lse = acc.lse();
        Ok((acc.finalize(), lse))
    }
}

fn fold_row(acc: &mut Partials, gi: usize, row: &[f32], stats: RowStats) {
    let d = acc.d;
    crate::attention::rescale_row(
        &mut acc.o[gi * d..(gi + 1) * d],
        &mut acc.stats[gi],
        row,
        stats,
    );
}

// Integration tests against the host oracle live in
// rust/tests/pjrt_attention.rs (they require built artifacts).
