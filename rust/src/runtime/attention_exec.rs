//! Decode attention over the PJRT kernel artifacts.
//!
//! Two execution paths:
//!
//! * [`AttentionExecutor::full`] — one fused kernel call per bucket
//!   (padding is exact because lengths are masked in-kernel).
//! * [`AttentionExecutor::lean`] — the LeanAttention path: a
//!   [`crate::partition::Plan`]'s CTA segments are chunked to the partial
//!   artifact's bucket, executed as batched partial-attention calls, and
//!   reduced in Rust with the softmax re-scaling operator (Alg 2 L24-39).
//!   Chunking a segment is exact for the same reason the paper's unequal
//!   splits are: the operator is associative.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::attention::{partial_attention_host, Partials, RowStats};
use crate::partition::cascade::{
    build_cascade_plan, CascadePlan, CascadeProblem, CascadeTensors, SegKind,
};
use crate::partition::multi_query::{MultiQueryInputs, MultiQueryProblem};
use crate::partition::plan::Plan;
use crate::sparse::selected_token_indices;

use super::artifacts::{AttentionKind, Manifest};
use super::client::{Executable, Runtime};
use super::tensor::HostTensor;

/// Decode-attention inputs in the repo's flattened-group layout:
/// `q: [g, d]`, `k/v: [g, n, d]` row-major, `lens[g]`.
pub struct AttentionProblem<'a> {
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub lens: &'a [u32],
    pub g: usize,
    pub n: usize,
    pub d: usize,
}

/// Compiles and caches attention artifacts; executes decode attention.
pub struct AttentionExecutor {
    runtime: Rc<Runtime>,
    manifest: Rc<Manifest>,
    cache: std::cell::RefCell<HashMap<String, Rc<Executable>>>,
}

impl AttentionExecutor {
    pub fn new(runtime: Rc<Runtime>, manifest: Rc<Manifest>) -> AttentionExecutor {
        AttentionExecutor {
            runtime,
            manifest,
            cache: Default::default(),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, file: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let exe = Rc::new(self.runtime.load_hlo(self.manifest.path_of(file))?);
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of distinct compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Exact decode attention through the fused `attn_full` artifact.
    /// Returns `(o: [g, d], lse: [g])`.
    pub fn full(&self, p: &AttentionProblem) -> Result<(Vec<f32>, Vec<f32>)> {
        let art = self
            .manifest
            .find_attention(AttentionKind::Full, p.d, p.g, p.n)
            .with_context(|| {
                format!("no full-attention bucket for g={} d={} ctx={}", p.g, p.d, p.n)
            })?;
        let exe = self.executable(&art.file)?;
        let (bg, bc, d) = (art.g, art.ctx, p.d);

        // Pad into the bucket (zeros + length masking make this exact).
        let mut q = vec![0.0f32; bg * d];
        let mut k = vec![0.0f32; bg * bc * d];
        let mut v = vec![0.0f32; bg * bc * d];
        let mut lens = vec![0i32; bg];
        for gi in 0..p.g {
            q[gi * d..(gi + 1) * d].copy_from_slice(&p.q[gi * d..(gi + 1) * d]);
            let src = gi * p.n * d;
            let dst = gi * bc * d;
            k[dst..dst + p.n * d].copy_from_slice(&p.k[src..src + p.n * d]);
            v[dst..dst + p.n * d].copy_from_slice(&p.v[src..src + p.n * d]);
            lens[gi] = p.lens[gi].min(p.n as u32) as i32;
        }

        let out = exe.run(&[
            HostTensor::f32(&[bg as i64, d as i64], q),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], k),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], v),
            HostTensor::i32(&[bg as i64], lens),
        ])?;
        let o_full = out[0].as_f32()?;
        let lse_full = out[1].as_f32()?;
        Ok((
            o_full[..p.g * d].to_vec(),
            lse_full[..p.g].to_vec(),
        ))
    }

    /// Un-scaled partial attention over a batch of same-width tasks via
    /// the `attn_partial` artifact. `q: [t, d]`, `kv: [t, w, d]`,
    /// `valid[t]`; returns `Partials` with `g = t`.
    fn partial_batch(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        valid: &[u32],
        t: usize,
        w: usize,
        d: usize,
    ) -> Result<Partials> {
        let art = self
            .manifest
            .find_attention(AttentionKind::Partial, d, t, w)
            .with_context(|| format!("no partial bucket for t={t} d={d} w={w}"))?;
        let exe = self.executable(&art.file)?;
        let (bg, bc) = (art.g, art.ctx);

        let mut qb = vec![0.0f32; bg * d];
        let mut kb = vec![0.0f32; bg * bc * d];
        let mut vb = vec![0.0f32; bg * bc * d];
        let mut validb = vec![0i32; bg];
        qb[..t * d].copy_from_slice(q);
        for ti in 0..t {
            let src = ti * w * d;
            let dst = ti * bc * d;
            kb[dst..dst + w * d].copy_from_slice(&k[src..src + w * d]);
            vb[dst..dst + w * d].copy_from_slice(&v[src..src + w * d]);
            validb[ti] = valid[ti].min(w as u32) as i32;
        }

        let out = exe.run(&[
            HostTensor::f32(&[bg as i64, d as i64], qb),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], kb),
            HostTensor::f32(&[bg as i64, bc as i64, d as i64], vb),
            HostTensor::i32(&[bg as i64], validb),
        ])?;
        let o = out[0].as_f32()?[..t * d].to_vec();
        let m = &out[1].as_f32()?[..t];
        let l = &out[2].as_f32()?[..t];
        Ok(Partials::from_flat(t, d, o, m, l))
    }

    /// LeanAttention: execute `plan`'s CTA segments through the partial
    /// artifact and reduce in Rust. Returns `(o: [g, d], lse: [g])`.
    pub fn lean(&self, p: &AttentionProblem, plan: &Plan) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = p.d;
        // Chunk tasks at LeanTile width and batch as many as the widest
        // available group bucket allows: padded work then tracks real work
        // (perf note in EXPERIMENTS.md §Perf — the previous
        // largest-bucket choice cost ~100x on small problems).
        let chunk_w = plan.tile;
        let batch_t = self
            .manifest
            .attention
            .iter()
            .filter(|a| a.kind == AttentionKind::Partial && a.d == d && a.ctx >= chunk_w)
            .map(|a| a.g)
            .max()
            .with_context(|| format!("no partial bucket for d={d}"))?;

        // Roll plan segments out into bucket-width tasks.
        struct Task {
            group: usize,
            start: usize, // token offset in the group's context
            width: usize,
        }
        let mut tasks = Vec::new();
        for cta in &plan.ctas {
            for seg in &cta.segments {
                let gi = seg.group as usize;
                let ctx = (p.lens[gi] as usize).min(p.n);
                let mut tok = seg.tile_begin as usize * plan.tile;
                let seg_end =
                    ((seg.tile_begin + seg.tile_count) as usize * plan.tile).min(p.n);
                while tok < seg_end {
                    let width = chunk_w.min(seg_end - tok);
                    // Tasks fully beyond the valid length contribute the
                    // identity; skip them outright.
                    if tok < ctx {
                        tasks.push(Task { group: gi, start: tok, width });
                    }
                    tok += width;
                }
            }
        }

        // Execute tasks in batches of the artifact's group capacity.
        let mut acc = Partials::identity(p.g, d);
        let mut qb = Vec::new();
        let mut kb = Vec::new();
        let mut vb = Vec::new();
        let mut valid = Vec::new();
        let mut groups = Vec::new();
        for chunk in tasks.chunks(batch_t) {
            qb.clear();
            kb.clear();
            vb.clear();
            valid.clear();
            groups.clear();
            let w = chunk.iter().map(|t| t.width).max().unwrap();
            for task in chunk {
                let gi = task.group;
                qb.extend_from_slice(&p.q[gi * d..(gi + 1) * d]);
                let base = gi * p.n * d + task.start * d;
                kb.extend_from_slice(&p.k[base..base + task.width * d]);
                vb.extend_from_slice(&p.v[base..base + task.width * d]);
                // pad narrower tasks inside this batch to width w
                for _ in task.width..w {
                    kb.extend(std::iter::repeat(0.0).take(d));
                    vb.extend(std::iter::repeat(0.0).take(d));
                }
                let ctx = p.lens[gi] as usize;
                valid.push(ctx.saturating_sub(task.start).min(task.width) as u32);
                groups.push(gi);
            }
            let part =
                self.partial_batch(&qb, &kb, &vb, &valid, chunk.len(), w, d)?;
            // Fold each task's row into its group's accumulator.
            for (ti, &gi) in groups.iter().enumerate() {
                let row = &part.o[ti * d..(ti + 1) * d];
                let stats = part.stats[ti];
                fold_row(&mut acc, gi, row, stats);
            }
        }

        let lse = acc.lse();
        Ok((acc.finalize(), lse))
    }

    /// Cascade LeanAttention through the PJRT partial artifact: a
    /// [`CascadePlan`]'s shared-prefix segments are rolled into tasks whose
    /// KV slice is materialized **once per task** and serves every member
    /// query row of the prefix group (one KV stream, many query rows);
    /// suffix segments execute per-sequence exactly like [`Self::lean`].
    /// All partials fold into the per-output accumulator with the
    /// group-broadcast rescale operator (Alg 2 L24-39 extended to shared
    /// groups). Returns `(o: [batch*heads, d], lse: [batch*heads])` in
    /// [`crate::partition::cascade::execute_cascade_host`]'s output layout.
    pub fn lean_cascade(
        &self,
        problem: &CascadeProblem,
        t: &CascadeTensors,
        cplan: &CascadePlan,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = problem.head_dim;
        let chunk_w = cplan.plan.tile;
        // Same bucket policy as `lean`: batch as many tasks as the widest
        // available partial group bucket allows.
        let batch_rows = self
            .manifest
            .attention
            .iter()
            .filter(|a| a.kind == AttentionKind::Partial && a.d == d && a.ctx >= chunk_w)
            .map(|a| a.g)
            .max()
            .with_context(|| format!("no partial bucket for d={d} ctx>={chunk_w}"))?;
        let tasks = roll_cascade_tasks(problem, cplan);
        run_cascade_tasks(problem, t, &tasks, batch_rows, |q, k, v, valid, rows, w| {
            self.partial_batch(q, k, v, valid, rows, w, d)
        })
    }

    /// Sparse lean attention through the PJRT partial artifact: each
    /// sequence's context is restricted to its **selected pages**
    /// ([`crate::sparse::select_pages`] ordinals over `page_tokens`-token
    /// pages), compacted in context order, and executed through the same
    /// task-rolling + fold driver as [`Self::lean_cascade`]. Exact over
    /// the selected rows by the same associativity argument as every
    /// other lean path; a selection covering every page reproduces the
    /// dense lean result. Returns `(o: [batch*heads, d], lse)` in the
    /// input row layout.
    #[allow(clippy::too_many_arguments)]
    pub fn lean_sparse(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        lens: &[u32],
        heads: usize,
        kv_heads: usize,
        n: usize,
        d: usize,
        page_tokens: usize,
        selections: &[Vec<usize>],
        tile: usize,
        sm_slots: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (cp, t) = sparse_compact_problem(
            q, k, v, lens, heads, kv_heads, n, d, page_tokens, selections, tile,
        )?;
        let cplan = build_cascade_plan(&cp, sm_slots);
        self.lean_cascade(&cp, &t, &cplan)
    }

    /// Multi-query lean attention — the speculative-decoding verify
    /// pass: `q_len` query rows per sequence (pending token + drafts,
    /// causal within the block) served by **one** walk of each cached
    /// context. The [`MultiQueryProblem`] expands into a cascade problem
    /// whose prefix groups carry the per-block (and fork-family) KV
    /// sharing, then executes through the identical task-rolling +
    /// group-broadcast-fold driver as [`Self::lean_cascade`]. Returns
    /// `(o: [rows * heads, d], lse: [rows * heads])` in expanded row
    /// order (`MultiQueryProblem::seq_of_row` maps rows back).
    pub fn lean_multi_query(
        &self,
        problem: &MultiQueryProblem,
        inputs: &MultiQueryInputs,
        sm_slots: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (cp, t) = problem.tensors(inputs)?;
        let cplan = build_cascade_plan(&cp, sm_slots);
        self.lean_cascade(&cp, &t, &cplan)
    }
}

/// Artifact-free twin of [`AttentionExecutor::lean_multi_query`]: the
/// same expansion and driver over the host partial oracle. The tier-1
/// property tests drive this against dense exact attention with
/// staggered causal lengths (`rust/tests/spec_props.rs`).
pub fn lean_multi_query_host(
    problem: &MultiQueryProblem,
    inputs: &MultiQueryInputs,
    sm_slots: usize,
    batch_rows: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let (cp, t) = problem.tensors(inputs)?;
    let cplan = build_cascade_plan(&cp, sm_slots);
    Ok(lean_cascade_host(&cp, &t, &cplan, batch_rows))
}

/// Pose the flat compacted problem a sparse page selection describes:
/// sequence `s`'s `[kv_heads, n, d]` KV rows (inside the kv-head-plane
/// `[batch*kv_heads, n, d]` layout, valid up to `lens[s]`) restricted to
/// the token spans of its selected page ordinals, packed in context
/// order. `q` stays at query-head rows (`[batch*heads, d]`). The result
/// is a group-free [`CascadeProblem`] over the compacted lengths — the
/// dense oracle restricted to the same pages (with KV repeated to query
/// heads under GQA) is exact attention over these tensors.
#[allow(clippy::too_many_arguments)]
pub fn sparse_compact_problem(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    lens: &[u32],
    heads: usize,
    kv_heads: usize,
    n: usize,
    d: usize,
    page_tokens: usize,
    selections: &[Vec<usize>],
    tile: usize,
) -> Result<(CascadeProblem, CascadeTensors)> {
    let batch = lens.len();
    anyhow::ensure!(selections.len() == batch, "one selection per sequence");
    anyhow::ensure!(q.len() == batch * heads * d, "q shape");
    anyhow::ensure!(k.len() == batch * kv_heads * n * d, "k shape");
    anyhow::ensure!(v.len() == k.len(), "v shape");
    let mut ctx_lens = Vec::with_capacity(batch);
    let mut k_suffix = Vec::with_capacity(batch);
    let mut v_suffix = Vec::with_capacity(batch);
    for (s, selection) in selections.iter().enumerate() {
        let idx = selected_token_indices(lens[s] as usize, page_tokens, selection);
        let sel_len = idx.len();
        let mut ks = vec![0.0f32; kv_heads * sel_len * d];
        let mut vs = vec![0.0f32; ks.len()];
        for h in 0..kv_heads {
            let row = (s * kv_heads + h) * n;
            for (j, &t) in idx.iter().enumerate() {
                anyhow::ensure!(t < n, "selected token {t} outside the KV view");
                let src = (row + t) * d;
                let dst = (h * sel_len + j) * d;
                ks[dst..dst + d].copy_from_slice(&k[src..src + d]);
                vs[dst..dst + d].copy_from_slice(&v[src..src + d]);
            }
        }
        ctx_lens.push(sel_len as u32);
        k_suffix.push(ks);
        v_suffix.push(vs);
    }
    let cp = CascadeProblem::new(heads, ctx_lens, d, Vec::new())?
        .with_tile(tile)
        .with_kv_heads(kv_heads);
    let t = CascadeTensors {
        q: q.to_vec(),
        k_shared: Vec::new(),
        v_shared: Vec::new(),
        k_suffix,
        v_suffix,
    };
    Ok((cp, t))
}

/// Artifact-free twin of [`AttentionExecutor::lean_sparse`]: the same
/// compaction and driver over the host partial oracle. The tier-1
/// property tests drive this against dense exact attention restricted to
/// the selected pages (`rust/tests/sparse_props.rs`) — the oracle half of
/// the engine's sparse decode gather.
#[allow(clippy::too_many_arguments)]
pub fn lean_sparse_host(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    lens: &[u32],
    heads: usize,
    kv_heads: usize,
    n: usize,
    d: usize,
    page_tokens: usize,
    selections: &[Vec<usize>],
    tile: usize,
    sm_slots: usize,
    batch_rows: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let (cp, t) = sparse_compact_problem(
        q, k, v, lens, heads, kv_heads, n, d, page_tokens, selections, tile,
    )?;
    let cplan = build_cascade_plan(&cp, sm_slots);
    Ok(lean_cascade_host(&cp, &t, &cplan, batch_rows))
}

/// One partial-attention task rolled out of a cascade plan: a contiguous
/// KV slice of one segment-problem lane, chunked at the plan's LeanTile
/// width. A `Shared` task serves every member query of its prefix group
/// from the single slice; a `Suffix` task serves one sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CascadeTask {
    /// Which lane (shared prefix stream or private suffix) the slice
    /// belongs to.
    pub kind: SegKind,
    /// Token offset within the lane's KV stream.
    pub start: usize,
    /// Tokens covered (clamped to the lane's context).
    pub width: usize,
}

/// Roll a cascade plan's CTA segments into [`CascadeTask`]s. Shared-prefix
/// slices appear **once per task** regardless of group size — that is the
/// KV-stream dedup the cascade executor banks over the flat lean path.
/// Tiles beyond a lane's context contribute the identity and are skipped.
pub fn roll_cascade_tasks(problem: &CascadeProblem, cplan: &CascadePlan) -> Vec<CascadeTask> {
    let tile = cplan.plan.tile;
    let mut tasks = Vec::new();
    for cta in &cplan.plan.ctas {
        for seg in &cta.segments {
            let g = seg.group as usize;
            let ctx = cplan.segment_problem.ctx_for_group(g);
            let kind = problem.seg_kind(g);
            let mut tok = seg.tile_begin as usize * tile;
            let seg_end = ((seg.tile_begin + seg.tile_count) as usize * tile).min(ctx);
            while tok < seg_end {
                let width = tile.min(seg_end - tok);
                tasks.push(CascadeTask { kind, start: tok, width });
                tok += width;
            }
        }
    }
    tasks
}

/// K+V bytes a task list reads from its source KV streams (f32 storage).
/// Each task's slice counts **once** — shared slices are not multiplied by
/// group size — so this is exactly what the cascade executor gathers,
/// and, on a plan without prefix groups, what the flat lean path gathers.
pub fn rolled_kv_bytes(tasks: &[CascadeTask], head_dim: usize) -> usize {
    crate::obs::attrib::tasks_kv_bytes(tasks, head_dim) as usize
}

/// Resolve a task's K/V slice inside the deduplicated cascade tensors.
fn task_kv<'a>(
    problem: &CascadeProblem,
    t: &'a CascadeTensors,
    task: &CascadeTask,
) -> (&'a [f32], &'a [f32]) {
    let d = problem.head_dim;
    let n = task.width * d;
    match task.kind {
        SegKind::Shared { pg, head } => {
            let prefix = problem.prefix_groups[pg].prefix_len as usize;
            let base = (head * prefix + task.start) * d;
            (
                &t.k_shared[pg][base..base + n],
                &t.v_shared[pg][base..base + n],
            )
        }
        SegKind::Suffix { seq, head } => {
            let sl = (problem.ctx_lens[seq] - problem.prefix_of(seq)) as usize;
            let base = (head * sl + task.start) * d;
            (
                &t.k_suffix[seq][base..base + n],
                &t.v_suffix[seq][base..base + n],
            )
        }
    }
}

/// Execute rolled cascade tasks through `exec_partial` — the PJRT partial
/// artifact or the host oracle — in batches of at most `batch_rows` query
/// rows, folding every partial into the per-output accumulator with the
/// group-broadcast rescale fold. `exec_partial(q, k, v, valid, rows, w)`
/// computes un-scaled partials for `rows` tasks of padded width `w`.
///
/// A shared task expands to one query row per group member, all served by
/// the same KV slice: the slice is read from the source stream once and
/// duplicated in-buffer for the remaining member rows.
fn run_cascade_tasks<F>(
    problem: &CascadeProblem,
    t: &CascadeTensors,
    tasks: &[CascadeTask],
    batch_rows: usize,
    mut exec_partial: F,
) -> Result<(Vec<f32>, Vec<f32>)>
where
    F: FnMut(&[f32], &[f32], &[f32], &[u32], usize, usize) -> Result<Partials>,
{
    let d = problem.head_dim;
    let heads = problem.heads;
    let gs = problem.group_size();

    // Expand tasks to (task, output-row) pairs. A task's `head` is a kv
    // head: under GQA its slice serves all `gs` query heads of that
    // group. Rows of one task stay adjacent so they land in the same
    // artifact batch and reuse the materialized slice.
    let mut rows: Vec<(usize, usize)> = Vec::new();
    for (ti, task) in tasks.iter().enumerate() {
        match task.kind {
            SegKind::Shared { pg, head } => {
                for &m in &problem.prefix_groups[pg].members {
                    for j in 0..gs {
                        rows.push((ti, m as usize * heads + head * gs + j));
                    }
                }
            }
            SegKind::Suffix { seq, head } => {
                for j in 0..gs {
                    rows.push((ti, seq * heads + head * gs + j));
                }
            }
        }
    }

    let mut acc = Partials::identity(problem.outputs(), d);
    for chunk in rows.chunks(batch_rows.max(1)) {
        let w = chunk.iter().map(|&(ti, _)| tasks[ti].width).max().unwrap();
        let mut qb = Vec::with_capacity(chunk.len() * d);
        let mut kb = vec![0.0f32; chunk.len() * w * d];
        let mut vb = vec![0.0f32; chunk.len() * w * d];
        let mut valid = Vec::with_capacity(chunk.len());
        let mut targets = Vec::with_capacity(chunk.len());
        for (ri, &(ti, out)) in chunk.iter().enumerate() {
            let task = &tasks[ti];
            qb.extend_from_slice(&t.q[out * d..(out + 1) * d]);
            let dst = ri * w * d;
            if ri > 0 && chunk[ri - 1].0 == ti {
                // Same shared slice as the previous row: duplicate the
                // already-materialized copy instead of re-reading the
                // source KV stream.
                let prev = (ri - 1) * w * d;
                kb.copy_within(prev..prev + task.width * d, dst);
                vb.copy_within(prev..prev + task.width * d, dst);
            } else {
                let (ks, vs) = task_kv(problem, t, task);
                kb[dst..dst + task.width * d].copy_from_slice(ks);
                vb[dst..dst + task.width * d].copy_from_slice(vs);
            }
            valid.push(task.width as u32);
            targets.push(out);
        }
        let part = exec_partial(&qb, &kb, &vb, &valid, chunk.len(), w)?;
        acc.fold_group_broadcast(&part, &targets);
    }

    let lse = acc.lse();
    Ok((acc.finalize(), lse))
}

/// Cascade LeanAttention on host numbers through the same task-rolling,
/// batching and group-broadcast fold as [`AttentionExecutor::lean_cascade`]
/// — its artifact-free twin, which the tier-1 property tests drive against
/// the exact oracle. `batch_rows` emulates the partial bucket's group
/// capacity.
pub fn lean_cascade_host(
    problem: &CascadeProblem,
    t: &CascadeTensors,
    cplan: &CascadePlan,
    batch_rows: usize,
) -> (Vec<f32>, Vec<f32>) {
    lean_cascade_host_traced(problem, t, cplan, batch_rows, &crate::obs::Tracer::disabled())
}

/// [`lean_cascade_host`] with the two hot phases traced: a `gather` span
/// over task rolling (carrying the deduplicated KV bytes the tasks will
/// stream) and a `lean_exec` span over the batched partial execution and
/// re-scaling reduction. With a disabled tracer this is exactly the
/// untraced path — `leanattn bench --obs` measures that bound.
pub fn lean_cascade_host_traced(
    problem: &CascadeProblem,
    t: &CascadeTensors,
    cplan: &CascadePlan,
    batch_rows: usize,
    tracer: &crate::obs::Tracer,
) -> (Vec<f32>, Vec<f32>) {
    use crate::obs::{Attrs, Phase};
    let d = problem.head_dim;
    let gather_start = tracer.now();
    let tasks = roll_cascade_tasks(problem, cplan);
    // Work attribution comes from the same accounting the simulator and
    // bench reports price — modeled and traced work cannot drift.
    let work = if tracer.is_enabled() {
        crate::obs::attrib::account_cascade_tasks(problem, &tasks)
    } else {
        crate::obs::attrib::WorkAccounting::default()
    };
    tracer.record_since(
        Phase::Gather,
        gather_start,
        Attrs { bytes: Some(work.gathered_kv_bytes), ..Default::default() },
    );
    let exec_start = tracer.now();
    let out = run_cascade_tasks(problem, t, &tasks, batch_rows, |q, k, v, valid, rows, w| {
        Ok(partial_attention_host(q, k, v, rows, w, d, valid, 0))
    })
    .expect("host partials cannot fail");
    tracer.record_since(
        Phase::LeanExec,
        exec_start,
        Attrs {
            k: Some(tasks.len()),
            flops: Some(work.softmax_flops),
            ..Default::default()
        },
    );
    out
}

fn fold_row(acc: &mut Partials, gi: usize, row: &[f32], stats: RowStats) {
    let d = acc.d;
    crate::attention::rescale_row(
        &mut acc.o[gi * d..(gi + 1) * d],
        &mut acc.stats[gi],
        row,
        stats,
    );
}

// Integration tests against the host oracle live in
// rust/tests/pjrt_attention.rs (they require built artifacts).
