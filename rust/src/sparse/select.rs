//! Query-aware top-k page selection over per-page key statistics.
//!
//! A page's score is the Quest-style upper bound on any `q · k` inside it:
//! `Σ_d max(q_d · min_d, q_d · max_d)` over the `[layers, h_kv, head_dim]`
//! channel plane — no key in the page can score higher against `q`, so
//! ranking pages by this bound never drops the page holding the true
//! argmax key. Selection always retains the sink pages and the recent
//! window ([`SparsePolicy`]), fills the remaining budget with the
//! top-scored middle pages (ties to the earlier page, deterministically),
//! and returns ordinals in ascending context order so the compacted
//! gather preserves token order.

use std::cmp::Ordering;

use super::page_meta::PageMeta;
use super::policy::SparsePolicy;

/// Upper bound on `q · k` over every K row the page's statistics cover.
/// `q` is one `[layers, h_kv, head_dim]` query-proxy row (the same
/// channel plane as the statistics). An empty page scores `-inf`.
pub fn page_upper_bound(q: &[f32], meta: &PageMeta) -> f32 {
    assert_eq!(q.len(), meta.k_min().len(), "query plane mismatch");
    if meta.filled() == 0 {
        return f32::NEG_INFINITY;
    }
    let mut s = 0.0f32;
    for ((&qd, &lo), &hi) in q.iter().zip(meta.k_min()).zip(meta.k_max()) {
        s += (qd * lo).max(qd * hi);
    }
    s
}

/// Per-group aggregate of [`page_upper_bound`] under GQA/MQA: a KV head's
/// page serves a whole group of query heads, so its score is the **max**
/// of the bound over every member query-proxy row. Ranking by this
/// aggregate never drops the page holding *any* member's best key — the
/// same admissibility the single-query bound gives, lifted to the group.
/// An empty group (or an empty page) scores `-inf`.
pub fn group_upper_bound<Q: AsRef<[f32]>>(queries: &[Q], meta: &PageMeta) -> f32 {
    queries
        .iter()
        .map(|q| page_upper_bound(q.as_ref(), meta))
        .fold(f32::NEG_INFINITY, f32::max)
}

/// Pick the page ordinals (indices into a sequence's page list) to stream
/// this step: all of them when the policy bypasses, otherwise sinks +
/// top-k middle pages by score + the recent window, ascending. The
/// result always satisfies `len <= max(budget, sinks + window)` and is a
/// superset of the sink and window ordinals.
pub fn select_pages(policy: &SparsePolicy, scores: &[f32]) -> Vec<usize> {
    let total = scores.len();
    let budget = policy.effective_pages(total);
    if budget >= total {
        return (0..total).collect();
    }
    let (sink, window) = policy.retention(total);
    let k = budget - sink - window;
    // Top-k of the middle by (score desc, ordinal asc) — a strict total
    // order, so the winner set is deterministic. An O(middle) partition
    // instead of a full sort: this runs per lane per decode step, on the
    // exact hot path the subsystem exists to shrink.
    let mut middle: Vec<usize> = (sink..total - window).collect();
    if k < middle.len() {
        middle.select_nth_unstable_by(k, |&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap_or(Ordering::Equal)
                .then(a.cmp(&b))
        });
        middle.truncate(k);
    }
    let mut sel: Vec<usize> = (0..sink)
        .chain(middle)
        .chain(total - window..total)
        .collect();
    sel.sort_unstable();
    sel
}

/// Softmax-weighted share of the per-page upper-bound scores a selection
/// covers — a cheap proxy for attention-mass coverage (the bound caps the
/// max logit in each page, so its exp-weight approximates the page's
/// share of softmax mass). 1.0 when everything is selected.
pub fn score_coverage(scores: &[f32], selected: &[usize]) -> f64 {
    let m = scores
        .iter()
        .copied()
        .filter(|s| s.is_finite())
        .fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return 1.0;
    }
    let weight = |s: f32| -> f64 {
        if s.is_finite() {
            f64::from(s - m).exp()
        } else {
            0.0
        }
    };
    let total: f64 = scores.iter().map(|&s| weight(s)).sum();
    if total <= 0.0 {
        return 1.0;
    }
    let covered: f64 = selected.iter().map(|&i| weight(scores[i])).sum();
    (covered / total).min(1.0)
}

/// Token indices (ascending) of a `len`-token context that a page
/// selection keeps: full `page_tokens`-token spans per ordinal, the tail
/// ordinal clamped to the context length.
pub fn selected_token_indices(
    len: usize,
    page_tokens: usize,
    selection: &[usize],
) -> Vec<usize> {
    let mut idx = Vec::new();
    for &o in selection {
        let start = o * page_tokens;
        for t in start..(start + page_tokens).min(len) {
            idx.push(t);
        }
    }
    idx
}

/// Tokens a selection streams out of a `len`-token context.
pub fn selected_tokens(len: usize, page_tokens: usize, selection: &[usize]) -> usize {
    selection
        .iter()
        .map(|&o| page_tokens.min(len.saturating_sub(o * page_tokens)))
        .sum()
}

/// K+V bytes a selection streams out of a `len`-token context, given the
/// cache's per-token K+V footprint — the `bytes` attribute the gather
/// span and the sparse bandwidth accounting both report.
pub fn selected_kv_bytes(
    len: usize,
    page_tokens: usize,
    selection: &[usize],
    token_bytes: usize,
) -> u64 {
    selected_tokens(len, page_tokens, selection) as u64 * token_bytes as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn meta_of(rows: &[Vec<f32>]) -> PageMeta {
        let mut m = PageMeta::empty(rows[0].len());
        for (slot, r) in rows.iter().enumerate() {
            m.observe(0, r);
            m.commit_row(slot);
        }
        m
    }

    #[test]
    fn upper_bound_dominates_every_row_score() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let d = 6;
            let rows: Vec<Vec<f32>> =
                (0..4).map(|_| rng.normal_vec(d)).collect();
            let m = meta_of(&rows);
            let q = rng.normal_vec(d);
            let bound = page_upper_bound(&q, &m);
            for r in &rows {
                let dot: f32 = q.iter().zip(r).map(|(&a, &b)| a * b).sum();
                assert!(
                    dot <= bound + 1e-5,
                    "row score {dot} exceeds bound {bound}"
                );
            }
        }
    }

    #[test]
    fn group_bound_is_the_max_over_member_queries() {
        let mut rng = Rng::new(11);
        let d = 6;
        let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(d)).collect();
        let m = meta_of(&rows);
        let members: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(d)).collect();
        let agg = group_upper_bound(&members, &m);
        let mut best = f32::NEG_INFINITY;
        for q in &members {
            let b = page_upper_bound(q, &m);
            assert!(b <= agg, "member bound {b} exceeds aggregate {agg}");
            best = best.max(b);
        }
        assert_eq!(agg, best);
        // One member degenerates to the single-query bound; an empty
        // group is -inf (no query can score the page).
        assert_eq!(group_upper_bound(&members[..1], &m), page_upper_bound(&members[0], &m));
        let none: [Vec<f32>; 0] = [];
        assert_eq!(group_upper_bound(&none, &m), f32::NEG_INFINITY);
    }

    #[test]
    fn empty_page_scores_neg_inf() {
        let m = PageMeta::empty(3);
        assert_eq!(page_upper_bound(&[1.0, -1.0, 0.5], &m), f32::NEG_INFINITY);
    }

    #[test]
    fn selection_keeps_sinks_window_and_top_middle() {
        let policy = SparsePolicy {
            budget_pages: 4,
            sink_pages: 1,
            window_pages: 1,
            dense_threshold_pages: 4,
        };
        // 8 pages; middle scores peak at ordinals 5 then 2.
        let scores = [0.0, -1.0, 3.0, -2.0, 0.5, 9.0, -3.0, 0.0];
        let sel = select_pages(&policy, &scores);
        assert_eq!(sel, vec![0, 2, 5, 7]);
    }

    #[test]
    fn ties_break_to_the_earlier_page() {
        let policy = SparsePolicy {
            budget_pages: 3,
            sink_pages: 1,
            window_pages: 1,
            dense_threshold_pages: 0,
        };
        let scores = [0.0, 2.0, 2.0, 2.0, 0.0];
        assert_eq!(select_pages(&policy, &scores), vec![0, 1, 4]);
    }

    #[test]
    fn budget_at_or_above_context_selects_everything() {
        let policy = SparsePolicy::with_budget(5);
        for n in 1..=5 {
            let scores = vec![0.0f32; n];
            assert_eq!(
                select_pages(&policy, &scores),
                (0..n).collect::<Vec<_>>()
            );
        }
        // Even with the threshold disabled, a covering budget is dense.
        let eager = SparsePolicy { dense_threshold_pages: 0, ..policy };
        assert_eq!(select_pages(&eager, &[0.0; 5]), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn coverage_is_one_when_all_selected_and_less_otherwise() {
        let scores = [5.0, 1.0, 0.0, 4.0];
        let all: Vec<usize> = (0..4).collect();
        assert!((score_coverage(&scores, &all) - 1.0).abs() < 1e-12);
        let some = score_coverage(&scores, &[0, 3]);
        assert!(some > 0.5 && some < 1.0, "coverage {some}");
        assert!(score_coverage(&scores, &[0, 3]) > score_coverage(&scores, &[1, 2]));
    }

    #[test]
    fn token_index_helpers_clamp_the_tail_page() {
        let idx = selected_token_indices(10, 4, &[0, 2]);
        assert_eq!(idx, vec![0, 1, 2, 3, 8, 9]);
        assert_eq!(selected_tokens(10, 4, &[0, 2]), 6);
        assert_eq!(selected_tokens(10, 4, &[0, 1, 2]), 10);
        assert_eq!(selected_kv_bytes(10, 4, &[0, 2], 16), 96);
    }
}
