//! The knobs of sparse page selection.

use anyhow::{ensure, Result};

/// Page-selection policy for sparse long-context decode: how many context
/// pages each sequence may stream per step, which pages are retained
/// unconditionally, and when selection is bypassed entirely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SparsePolicy {
    /// Total pages a sequence streams per decode step (sinks and the
    /// recent window included). Floors at `sink_pages + window_pages`.
    pub budget_pages: usize,
    /// Leading pages always retained — the attention-sink prefix whose
    /// removal is known to destroy long-context quality.
    pub sink_pages: usize,
    /// Trailing pages always retained — the recency window (the partial
    /// tail page the step appends into is its last member).
    pub window_pages: usize,
    /// Contexts of at most this many pages skip selection and stream
    /// dense — scoring overhead cannot pay for itself on short contexts.
    pub dense_threshold_pages: usize,
}

impl SparsePolicy {
    /// A policy with the default sink (1 page) and window (2 pages)
    /// retention and a dense threshold equal to the budget (selection
    /// engages exactly when the context no longer fits it).
    pub fn with_budget(budget_pages: usize) -> SparsePolicy {
        SparsePolicy {
            budget_pages,
            sink_pages: 1,
            window_pages: 2,
            dense_threshold_pages: budget_pages,
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.budget_pages >= 1, "kv budget must be >= 1 page");
        ensure!(
            self.budget_pages >= self.sink_pages + self.window_pages,
            "kv budget of {} pages cannot hold {} sink + {} window pages",
            self.budget_pages,
            self.sink_pages,
            self.window_pages
        );
        Ok(())
    }

    /// Whether a context of `total_pages` pages streams dense (no
    /// scoring, no selection — the short-context fallback).
    pub fn bypasses(&self, total_pages: usize) -> bool {
        total_pages <= self.dense_threshold_pages
    }

    /// Sink/window retention clamped to a `total_pages` context.
    pub fn retention(&self, total_pages: usize) -> (usize, usize) {
        let sink = self.sink_pages.min(total_pages);
        let window = self.window_pages.min(total_pages - sink);
        (sink, window)
    }

    /// Pages a `total_pages`-page context actually streams under this
    /// policy: everything when bypassed or covered by the budget,
    /// otherwise the budget floored at the retention. The selector
    /// ([`crate::sparse::select_pages`]) and the byte model
    /// ([`crate::sim::sparse`]) both derive their counts from here, so
    /// they can never drift apart.
    pub fn effective_pages(&self, total_pages: usize) -> usize {
        if self.bypasses(total_pages) || self.budget_pages >= total_pages {
            return total_pages;
        }
        let (sink, window) = self.retention(total_pages);
        self.budget_pages.clamp(sink + window, total_pages)
    }

    /// Whether a lane whose selection came back `(selected, scored)`
    /// routes through the sparse selected-page gather: every scored
    /// lane, plus complete (unscored) selections past the dense
    /// threshold — covering budgets stay on the proven-bit-identical
    /// selected-gather path instead of silently falling back to dense.
    /// The one predicate both the engine and the bench harness use, so
    /// their `selection_steps` counters mean the same thing.
    pub fn engages(&self, selected_pages: usize, scored: bool) -> bool {
        scored || !self.bypasses(selected_pages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_budget_defaults() {
        let p = SparsePolicy::with_budget(8);
        assert_eq!(p.sink_pages, 1);
        assert_eq!(p.window_pages, 2);
        assert_eq!(p.dense_threshold_pages, 8);
        p.validate().unwrap();
    }

    #[test]
    fn validation_rejects_budgets_below_retention() {
        let p = SparsePolicy {
            budget_pages: 2,
            sink_pages: 1,
            window_pages: 2,
            dense_threshold_pages: 0,
        };
        assert!(p.validate().is_err());
        assert!(SparsePolicy::with_budget(0).validate().is_err());
    }

    #[test]
    fn bypass_is_keyed_on_the_dense_threshold() {
        let p = SparsePolicy::with_budget(4);
        assert!(p.bypasses(4));
        assert!(!p.bypasses(5));
        let eager = SparsePolicy { dense_threshold_pages: 0, ..p };
        assert!(!eager.bypasses(1), "threshold 0 never bypasses");
    }

    #[test]
    fn effective_pages_clamps_and_covers() {
        let p = SparsePolicy::with_budget(6); // sink 1, window 2
        assert_eq!(p.effective_pages(4), 4, "covered context is dense");
        assert_eq!(p.effective_pages(6), 6);
        assert_eq!(p.effective_pages(20), 6, "budget binds");
        assert_eq!(p.retention(20), (1, 2));
        assert_eq!(p.retention(1), (1, 0), "window clamps after the sink");
        // A budget below retention floors at sink + window.
        let tight = SparsePolicy {
            budget_pages: 2,
            sink_pages: 2,
            window_pages: 2,
            dense_threshold_pages: 0,
        };
        assert_eq!(tight.effective_pages(10), 4);
    }
}
