//! Rotary-position correction for compacted sparse decode views.
//!
//! The decode/verify artifacts take one `positions` input that serves
//! both as the attention mask length and as the fresh token's rotary
//! position (`python/compile/model.py::_apply_rope`, rotate-half
//! convention). A sparse step masks to the **compacted** length, so the
//! artifact rotates the fresh Q/K at the compacted index instead of the
//! true one. For the transient query this is the standard packed-view
//! approximation — every cached key's relative angle shifts by the same
//! constant, as if the query sat right after the selected tokens — but
//! the fresh K row is **appended to the cache**, where a wrong rotation
//! would outlive the step and corrupt every later (even dense) read.
//! [`advance_rope`] fixes that: rotating by the position delta composes
//! exactly (`R(a + b) = R(b)·R(a)`), so advancing the artifact's K row
//! from the compacted to the true position reproduces what a dense step
//! would have written, up to f32 rounding — and a zero delta (dense and
//! covering-budget steps) skips the correction entirely, preserving
//! bit-identity.

/// Rotate every `head_dim`-sized row of `plane` forward by `delta`
/// positions under rotate-half RoPE with base `rope_base`. `plane` is
/// any concatenation of head rows (`[layers * heads, head_dim]`
/// row-major, e.g. a [`crate::coordinator::PagedKvCache`] token plane).
pub fn advance_rope(plane: &mut [f32], head_dim: usize, delta: f64, rope_base: f64) {
    if delta == 0.0 {
        return;
    }
    assert!(head_dim >= 2 && head_dim % 2 == 0, "rotary head_dim");
    assert_eq!(plane.len() % head_dim, 0, "plane of head rows");
    let half = head_dim / 2;
    // cos/sin per channel pair, shared by every head row.
    let mut cos = vec![0.0f32; half];
    let mut sin = vec![0.0f32; half];
    for j in 0..half {
        let inv = rope_base.powf(-(j as f64) / half as f64);
        let ang = delta * inv;
        cos[j] = ang.cos() as f32;
        sin[j] = ang.sin() as f32;
    }
    for row in plane.chunks_mut(head_dim) {
        for j in 0..half {
            let (a, b) = (row[j], row[j + half]);
            row[j] = a * cos[j] - b * sin[j];
            row[j + half] = a * sin[j] + b * cos[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Reference rotate-half RoPE at absolute position `pos` — mirrors
    /// `python/compile/model.py::_apply_rope`.
    fn rope_at(raw: &[f32], head_dim: usize, pos: f64, base: f64) -> Vec<f32> {
        let half = head_dim / 2;
        let mut out = raw.to_vec();
        for row in out.chunks_mut(head_dim) {
            for j in 0..half {
                let inv = base.powf(-(j as f64) / half as f64);
                let (c, s) = ((pos * inv).cos() as f32, (pos * inv).sin() as f32);
                let (a, b) = (row[j], row[j + half]);
                row[j] = a * c - b * s;
                row[j + half] = a * s + b * c;
            }
        }
        out
    }

    #[test]
    fn advancing_composes_to_the_true_position() {
        let mut rng = Rng::new(3);
        for (dh, pos, delta) in [(8usize, 5.0, 3.0), (16, 100.0, 77.0), (4, 0.0, 1.0)] {
            let raw = rng.normal_vec(3 * dh); // 3 head rows
            let mut got = rope_at(&raw, dh, pos, 10_000.0);
            advance_rope(&mut got, dh, delta, 10_000.0);
            let want = rope_at(&raw, dh, pos + delta, 10_000.0);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w} (dh {dh})");
            }
        }
    }

    #[test]
    fn zero_delta_is_a_bitwise_no_op() {
        let mut rng = Rng::new(4);
        let orig = rng.normal_vec(16);
        let mut x = orig.clone();
        advance_rope(&mut x, 8, 0.0, 10_000.0);
        assert_eq!(x, orig, "delta 0 must not touch the plane");
    }
}
