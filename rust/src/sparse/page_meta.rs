//! Per-page key statistics for query-aware page selection.
//!
//! Each KV page carries the channel-wise minimum and maximum of its
//! written K rows, laid out `[layers, h_kv, head_dim]` — kv-head granular
//! like the cache itself, so under GQA/MQA the statistics shrink with the
//! KV plane and one page's bounds serve every query head of the group.
//! They are enough to bound
//! `q · k` for every key in the page from above (Quest's criterion,
//! arXiv 2502.06766 §page-granular selection) without touching the rows
//! themselves. The statistics are maintained **incrementally** by
//! [`crate::coordinator::PagedKvCache`]: every K row written into a page
//! folds into the running min/max, a copy-on-write clone recomputes its
//! statistics over exactly the rows the cloning holder's view keeps, and
//! a truncation of an exclusively-held page shrinks the statistics to the
//! surviving rows. The invariant — statistics always equal a from-scratch
//! recompute over the page's `filled` rows, and `filled` covers every
//! holder's view — is property-tested in `rust/tests/kv_cache_props.rs`.

/// Running channel-wise min/max over the K rows written into one page.
#[derive(Clone, Debug, PartialEq)]
pub struct PageMeta {
    /// Rows the statistics cover (`0..filled` of the page's token slots).
    filled: usize,
    /// `[layers, h_kv, head_dim]` channel-wise minimum over filled rows.
    k_min: Vec<f32>,
    /// `[layers, h_kv, head_dim]` channel-wise maximum over filled rows.
    k_max: Vec<f32>,
}

impl PageMeta {
    /// Statistics of an empty page over a `plane`-channel K row
    /// (`layers * heads * head_dim`).
    pub fn empty(plane: usize) -> PageMeta {
        PageMeta {
            filled: 0,
            k_min: vec![f32::INFINITY; plane],
            k_max: vec![f32::NEG_INFINITY; plane],
        }
    }

    /// Reset to the empty state (page returned to the free list).
    pub fn reset(&mut self) {
        self.filled = 0;
        self.k_min.fill(f32::INFINITY);
        self.k_max.fill(f32::NEG_INFINITY);
    }

    /// Rows the statistics cover.
    pub fn filled(&self) -> usize {
        self.filled
    }

    /// Channel-wise minimum, `[layers, heads, head_dim]`.
    pub fn k_min(&self) -> &[f32] {
        &self.k_min
    }

    /// Channel-wise maximum, `[layers, heads, head_dim]`.
    pub fn k_max(&self) -> &[f32] {
        &self.k_max
    }

    /// Fold one `(layer, head)` K sub-row at channel `offset` into the
    /// running bounds. Callers fold every sub-row of a token and then
    /// [`Self::commit_row`] it.
    pub fn observe(&mut self, offset: usize, k_row: &[f32]) {
        for (i, &x) in k_row.iter().enumerate() {
            let c = offset + i;
            if x < self.k_min[c] {
                self.k_min[c] = x;
            }
            if x > self.k_max[c] {
                self.k_max[c] = x;
            }
        }
    }

    /// Mark token slot `slot` as covered. Writes are always sequential
    /// (the cache repairs statistics before any non-sequential write), so
    /// the slot extends the covered range by exactly one row.
    pub fn commit_row(&mut self, slot: usize) {
        debug_assert_eq!(
            slot, self.filled,
            "page statistics must cover rows contiguously"
        );
        self.filled = slot + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_meta_has_inverted_bounds() {
        let m = PageMeta::empty(4);
        assert_eq!(m.filled(), 0);
        assert!(m.k_min().iter().all(|&x| x == f32::INFINITY));
        assert!(m.k_max().iter().all(|&x| x == f32::NEG_INFINITY));
    }

    #[test]
    fn observe_and_commit_track_min_max() {
        let mut m = PageMeta::empty(4);
        m.observe(0, &[1.0, -2.0]);
        m.observe(2, &[0.5, 3.0]);
        m.commit_row(0);
        m.observe(0, &[-1.0, 5.0]);
        m.observe(2, &[0.5, -3.0]);
        m.commit_row(1);
        assert_eq!(m.filled(), 2);
        assert_eq!(m.k_min(), &[-1.0, -2.0, 0.5, -3.0]);
        assert_eq!(m.k_max(), &[1.0, 5.0, 0.5, 3.0]);
    }

    #[test]
    fn reset_restores_the_empty_state() {
        let mut m = PageMeta::empty(2);
        m.observe(0, &[1.0, 2.0]);
        m.commit_row(0);
        m.reset();
        assert_eq!(m, PageMeta::empty(2));
    }
}
