//! Sparse long-context decode: page-granular top-k KV selection.
//!
//! In the 512k-context decode regime every step streams the entire KV
//! history, yet attention mass concentrates on a small fraction of it —
//! page-granular top-k selection recovers near-full quality at a fraction
//! of the bytes (arXiv 2502.06766), bounding the per-step context cost
//! the lean partitioner walks (arXiv 2410.07063). This module scores and
//! prunes context *pages* before each decode step:
//!
//! * [`page_meta`] — per-page channel-wise K min/max, maintained
//!   incrementally by [`crate::coordinator::PagedKvCache`] and kept
//!   consistent across copy-on-write forks and rollback truncations;
//! * [`select`] — the Quest-style per-page upper bound
//!   `Σ_d max(q_d·min_d, q_d·max_d)` and deterministic top-k selection
//!   that always retains the sink pages and the recent window;
//! * [`policy`] — [`SparsePolicy`]: page budget, sink/window counts, and
//!   the dense fallback threshold below which selection is bypassed;
//! * [`rope`] — rotary-position correction: fresh K rows produced under
//!   a compacted view are advanced to their true positions before they
//!   enter the cache (exact by rotation composition).
//!
//! The serving half lives downstream: `PagedKvCache::gather_selected`
//! materializes only the selected pages (compacted, order-preserving),
//! the engine threads per-sequence selections through its decode and
//! spec-verify gathers, `runtime::attention_exec::lean_sparse_host` is
//! the executor twin property-tested exact against the dense oracle
//! restricted to the selected pages, `sim::sparse` models bytes saved and
//! attention-mass coverage vs budget, and `leanattn serve --kv-budget` /
//! `bench --sparse` / `simulate --sparse-budget` are the CLI surfaces.

pub mod page_meta;
pub mod policy;
pub mod rope;
pub mod select;

pub use page_meta::PageMeta;
pub use policy::SparsePolicy;
pub use rope::advance_rope;
pub use select::{
    group_upper_bound, page_upper_bound, score_coverage, select_pages,
    selected_kv_bytes, selected_token_indices, selected_tokens,
};
