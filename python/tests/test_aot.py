"""AOT pipeline tests: manifest integrity, HLO text well-formedness, and
weights-blob layout — the contract the Rust runtime depends on."""

from __future__ import annotations

import json
import pathlib
import struct

import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


class TestManifest:
    def test_version_and_sections(self, manifest):
        assert manifest["version"] == 1
        assert manifest["attention"]
        assert manifest["reduce"]
        assert "tiny" in manifest["models"]

    def test_every_artifact_file_exists_and_is_hlo(self, manifest):
        entries = list(manifest["attention"]) + list(manifest["reduce"])
        for m in manifest["models"].values():
            entries += [m["decode"], m["prefill"]]
        for e in entries:
            p = ART / e["file"]
            assert p.exists(), e["file"]
            text = p.read_text()
            assert text.startswith("HloModule"), e["file"]
            assert "ENTRY" in text, e["file"]
            assert len(text) == e["bytes"]

    def test_attention_buckets_cover_configured_grid(self, manifest):
        got = {
            (e["kind"], e["g"], e["d"], e["ctx"]) for e in manifest["attention"]
        }
        for g, d, c in aot.ATTN_BUCKETS:
            assert ("full", g, d, c) in got
            assert ("partial", g, d, c) in got

    def test_full_artifacts_declare_two_outputs(self, manifest):
        for e in manifest["attention"]:
            n_out = 2 if e["kind"] == "full" else 3
            assert len(e["outputs"]) == n_out


class TestWeightsBlob:
    def test_blob_size_matches_param_order(self, manifest):
        for name, m in manifest["models"].items():
            cfg = M.CONFIGS[name]
            expect = 4 * cfg.param_count()
            blob = (ART / m["weights"]).read_bytes()
            assert len(blob) == expect == m["weights_bytes"]

    def test_blob_round_trips_init_params(self, manifest):
        name = "tiny"
        cfg = M.CONFIGS[name]
        blob = (ART / manifest["models"][name]["weights"]).read_bytes()
        params = M.init_params(cfg, seed=0)
        off = 0
        for w in params:
            n = w.size * 4
            got = np.frombuffer(blob[off : off + n], dtype="<f4").reshape(w.shape)
            np.testing.assert_array_equal(got, w)
            off += n
        assert off == len(blob)

    def test_manifest_param_shapes(self, manifest):
        for name, m in manifest["models"].items():
            cfg = M.CONFIGS[name]
            assert [
                (p["name"], tuple(p["shape"])) for p in m["params"]
            ] == cfg.param_order()


class TestHloParamCount:
    """The HLO entry computation must take exactly the inputs the manifest
    declares — the Rust runtime feeds buffers positionally."""

    def _entry_param_count(self, text: str) -> int:
        # Parse the input tuple of `entry_computation_layout={(a, b, ...)->...}`.
        key = "entry_computation_layout={("
        start = text.index(key) + len(key)
        depth, count, i = 1, 1, start
        while depth > 0:
            ch = text[i]
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            elif ch == "," and depth == 1:
                count += 1
            i += 1
        if text[start:i - 1].strip() == "":
            return 0
        return count

    def test_attention_inputs(self, manifest):
        for e in manifest["attention"]:
            text = (ART / e["file"]).read_text()
            assert self._entry_param_count(text) == len(e["inputs"])

    def test_model_inputs(self, manifest):
        for name, m in manifest["models"].items():
            cfg = M.CONFIGS[name]
            n_params = len(cfg.param_order())
            dec = (ART / m["decode"]["file"]).read_text()
            assert self._entry_param_count(dec) == n_params + 4
            pre = (ART / m["prefill"]["file"]).read_text()
            assert self._entry_param_count(pre) == n_params + 2
