"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, dtypes, lengths, and tile sizes; every case
asserts allclose against ``kernels.ref``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lean_attention as la
from compile.kernels import ref


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def _case(rng, g, n, d, dtype, max_len=None):
    q = _rand(rng, (g, d), dtype)
    k = _rand(rng, (g, n, d), dtype)
    v = _rand(rng, (g, n, d), dtype)
    hi = max_len or n
    lens = jnp.asarray(rng.integers(1, hi + 1, g), dtype=jnp.int32)
    return q, k, v, lens


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("g,n,d", [(4, 256, 64), (8, 512, 64), (2, 256, 128)])
    def test_matches_ref(self, g, n, d, dtype):
        rng = np.random.default_rng(g * n + d)
        q, k, v, lens = _case(rng, g, n, d, dtype)
        o, lse = la.decode_attention(q, k, v, lens)
        o_ref = ref.attention_ref(q, k, v, lens)
        np.testing.assert_allclose(o, o_ref, atol=TOL[dtype], rtol=TOL[dtype])

    def test_length_one(self):
        """Shortest legal context: every group attends to a single token."""
        rng = np.random.default_rng(7)
        q, k, v, _ = _case(rng, 4, 256, 64, jnp.float32)
        lens = jnp.ones(4, jnp.int32)
        o, _ = la.decode_attention(q, k, v, lens)
        # softmax over one token is 1 -> output is v[:, 0]
        np.testing.assert_allclose(o, v[:, 0].astype(jnp.float32), atol=1e-6)

    def test_full_bucket(self):
        rng = np.random.default_rng(8)
        q, k, v, _ = _case(rng, 4, 512, 64, jnp.float32)
        lens = jnp.full(4, 512, jnp.int32)
        o, _ = la.decode_attention(q, k, v, lens)
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v, lens), atol=2e-5, rtol=2e-5
        )

    def test_lse_matches_naive(self):
        rng = np.random.default_rng(9)
        q, k, v, lens = _case(rng, 4, 256, 64, jnp.float32)
        _, lse = la.decode_attention(q, k, v, lens)
        s = jnp.einsum("gd,gnd->gn", q, k) / 8.0
        pos = jnp.arange(256)[None, :]
        s = jnp.where(pos < lens[:, None], s, -jnp.inf)
        naive = jax_logsumexp(s)
        np.testing.assert_allclose(lse[:, 0], naive, atol=1e-4, rtol=1e-5)

    def test_custom_tile_sizes_agree(self):
        rng = np.random.default_rng(10)
        q, k, v, lens = _case(rng, 4, 512, 64, jnp.float32)
        outs = [
            la.decode_attention(q, k, v, lens, block_t=t)[0]
            for t in (32, 64, 128, 256, 512)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=2e-5, rtol=2e-5)

    def test_extreme_scores_no_nan(self):
        """Large-magnitude logits must not overflow (online softmax)."""
        rng = np.random.default_rng(11)
        q, k, v, lens = _case(rng, 4, 256, 64, jnp.float32)
        q = q * 100.0
        o, lse = la.decode_attention(q, k, v, lens)
        assert np.isfinite(np.asarray(o)).all()
        assert np.isfinite(np.asarray(lse)).all()
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v, lens), atol=1e-4, rtol=1e-4
        )

    @settings(max_examples=40, deadline=None)
    @given(
        g=st.integers(1, 8),
        nblk=st.integers(1, 8),
        d=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_hypothesis_sweep(self, g, nblk, d, seed, dtype):
        tile = la.lean_tile_for(d)
        n = nblk * tile
        rng = np.random.default_rng(seed)
        q, k, v, lens = _case(rng, g, n, d, dtype)
        o, _ = la.decode_attention(q, k, v, lens)
        o_ref = ref.attention_ref(q, k, v, lens)
        np.testing.assert_allclose(o, o_ref, atol=TOL[dtype], rtol=TOL[dtype])


class TestPartialAttention:
    def test_partial_covers_whole_context_equals_full(self):
        rng = np.random.default_rng(20)
        q, k, v, lens = _case(rng, 4, 512, 64, jnp.float32)
        o, m, l = la.partial_attention(q, k, v, lens)
        of = ref.finalize_ref(o, l)
        np.testing.assert_allclose(
            of, ref.attention_ref(q, k, v, lens), atol=2e-5, rtol=2e-5
        )

    def test_matches_partial_ref(self):
        rng = np.random.default_rng(21)
        q, k, v, _ = _case(rng, 4, 256, 64, jnp.float32)
        valid = jnp.asarray([256, 100, 1, 7], jnp.int32)
        o, m, l = la.partial_attention(q, k, v, valid)
        ro, rm, rl = ref.partial_attention_ref(q, k, v, valid)
        np.testing.assert_allclose(o, ro, atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(m, rm, atol=1e-6)
        np.testing.assert_allclose(l, rl, atol=2e-5, rtol=2e-5)

    def test_fully_masked_slice_is_identity_element(self):
        """valid == 0 must produce (0, NEG_INF-ish, 0): zero weight."""
        rng = np.random.default_rng(22)
        q, k, v, _ = _case(rng, 4, 256, 64, jnp.float32)
        valid = jnp.zeros(4, jnp.int32)
        o, m, l = la.partial_attention(q, k, v, valid)
        np.testing.assert_array_equal(np.asarray(o), 0.0)
        np.testing.assert_array_equal(np.asarray(l), 0.0)
        assert (np.asarray(m) <= la.NEG_INF / 2).all()

    @settings(max_examples=30, deadline=None)
    @given(
        g=st.integers(1, 6),
        nblk=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_partials(self, g, nblk, seed):
        n = nblk * 128
        rng = np.random.default_rng(seed)
        q, k, v, _ = _case(rng, g, n, 64, jnp.float32)
        valid = jnp.asarray(rng.integers(0, n + 1, g), jnp.int32)
        o, m, l = la.partial_attention(q, k, v, valid, block_t=128)
        ro, rm, rl = ref.partial_attention_ref(q, k, v, valid)
        np.testing.assert_allclose(o, ro, atol=3e-5, rtol=3e-5)
        np.testing.assert_allclose(l, rl, atol=3e-5, rtol=3e-5)


class TestRescaleReduce:
    def _split_partials(self, rng, q, k, v, lens, bounds):
        parts = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            valid = jnp.clip(lens - lo, 0, hi - lo)
            # pad slices to a common width for the stacked kernel input
            parts.append(
                ref.partial_attention_ref(q, k[:, lo:hi], v[:, lo:hi], valid)
            )
        return parts

    def test_kernel_reduce_matches_full(self):
        rng = np.random.default_rng(30)
        q, k, v, lens = _case(rng, 4, 512, 64, jnp.float32)
        bounds = [0, 64, 65, 300, 512]  # deliberately unequal slices
        parts = self._split_partials(rng, q, k, v, lens, bounds)
        # stack with padding to widest slice handled by (o,m,l) being [G,*]
        o, lse = la.rescale_reduce(
            jnp.stack([p[0] for p in parts]),
            jnp.stack([p[1] for p in parts]),
            jnp.stack([p[2] for p in parts]),
        )
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v, lens), atol=2e-5, rtol=2e-5
        )

    def test_identity_slot_padding(self):
        """Padding the P axis with (0, NEG_INF, 0) must not change results."""
        rng = np.random.default_rng(31)
        q, k, v, lens = _case(rng, 4, 256, 64, jnp.float32)
        parts = self._split_partials(rng, q, k, v, lens, [0, 128, 256])
        g, d = 4, 64
        ident_o = jnp.zeros((1, g, d))
        ident_m = jnp.full((1, g, 1), ref.NEG_INF)
        ident_l = jnp.zeros((1, g, 1))
        o, _ = la.rescale_reduce(
            jnp.concatenate([jnp.stack([p[0] for p in parts]), ident_o]),
            jnp.concatenate([jnp.stack([p[1] for p in parts]), ident_m]),
            jnp.concatenate([jnp.stack([p[2] for p in parts]), ident_l]),
        )
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v, lens), atol=2e-5, rtol=2e-5
        )


class TestAssociativity:
    """The paper's §IV-A theorem, property-tested end to end in jnp."""

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        nsplits=st.integers(0, 6),
        order=st.sampled_from(["left", "right", "tree"]),
    )
    def test_any_split_any_order(self, seed, nsplits, order):
        rng = np.random.default_rng(seed)
        g, n, d = 3, 384, 64
        q, k, v, lens = _case(rng, g, n, d, jnp.float32)
        splits = sorted(rng.integers(1, n, nsplits).tolist())
        o = ref.lean_attention_ref(q, k, v, lens, splits, reduce_order=order)
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v, lens), atol=3e-5, rtol=3e-5
        )

    def test_pairwise_commutative_in_value(self):
        """f(x,y) and f(y,x) finalize to the same output (order of the
        *reduction arguments* is free; linearity of the numerator)."""
        rng = np.random.default_rng(40)
        g, n, d = 4, 256, 64
        q, k, v, lens = _case(rng, g, n, d, jnp.float32)
        px = ref.partial_attention_ref(q, k[:, :100], v[:, :100], jnp.minimum(lens, 100))
        py = ref.partial_attention_ref(
            q, k[:, 100:], v[:, 100:], jnp.clip(lens - 100, 0, n - 100)
        )
        oxy = ref.finalize_ref(
            ref.rescale_reduce_ref(*px, *py)[0], ref.rescale_reduce_ref(*px, *py)[2]
        )
        oyx = ref.finalize_ref(
            ref.rescale_reduce_ref(*py, *px)[0], ref.rescale_reduce_ref(*py, *px)[2]
        )
        np.testing.assert_allclose(oxy, oyx, atol=1e-6)


def jax_logsumexp(s):
    import jax

    return jax.scipy.special.logsumexp(s, axis=-1)
