"""L2 model tests: decode step (Pallas path) vs dense oracle, prefill→decode
consistency, shape contracts the Rust runtime relies on."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref as kref

CFG = M.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def params():
    return [jnp.asarray(w) for w in M.init_params(CFG, seed=0)]


def _random_cache(rng, cfg):
    shape = (cfg.n_layers, cfg.batch, cfg.n_heads, cfg.ctx_bucket, cfg.head_dim)
    return (
        jnp.asarray(rng.standard_normal(shape), jnp.float32),
        jnp.asarray(rng.standard_normal(shape), jnp.float32),
    )


class TestDecodeStep:
    def test_matches_dense_oracle(self, params):
        rng = np.random.default_rng(0)
        kc, vc = _random_cache(rng, CFG)
        toks = jnp.asarray(rng.integers(0, CFG.vocab, CFG.batch), jnp.int32)
        pos = jnp.asarray([5, CFG.ctx_bucket - 1], jnp.int32)
        lg1, nk1, nv1 = M.decode_step(CFG, params, toks, kc, vc, pos)
        lg2, nk2, nv2 = M.decode_step_dense(CFG, params, toks, kc, vc, pos)
        np.testing.assert_allclose(lg1, lg2, atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(nk1, nk2, atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(nv1, nv2, atol=5e-5, rtol=5e-5)

    def test_output_shapes(self, params):
        rng = np.random.default_rng(1)
        kc, vc = _random_cache(rng, CFG)
        toks = jnp.zeros(CFG.batch, jnp.int32)
        pos = jnp.ones(CFG.batch, jnp.int32)
        lg, nk, nv = M.decode_step(CFG, params, toks, kc, vc, pos)
        assert lg.shape == (CFG.batch, CFG.vocab)
        assert nk.shape == (CFG.n_layers, CFG.batch, CFG.n_heads, CFG.head_dim)
        assert nv.shape == nk.shape

    def test_position_zero_uses_only_fresh_token(self, params):
        """pos == 0: cache contributes nothing; garbage cache must not leak."""
        rng = np.random.default_rng(2)
        kc, vc = _random_cache(rng, CFG)
        kc2 = kc * 1e3  # wildly different garbage
        vc2 = vc * -7.0
        toks = jnp.asarray(rng.integers(0, CFG.vocab, CFG.batch), jnp.int32)
        pos = jnp.zeros(CFG.batch, jnp.int32)
        lg1, _, _ = M.decode_step(CFG, params, toks, kc, vc, pos)
        lg2, _, _ = M.decode_step(CFG, params, toks, kc2, vc2, pos)
        np.testing.assert_allclose(lg1, lg2, atol=1e-5)

    def test_deterministic(self, params):
        rng = np.random.default_rng(3)
        kc, vc = _random_cache(rng, CFG)
        toks = jnp.asarray([1, 2], jnp.int32)
        pos = jnp.asarray([3, 4], jnp.int32)
        a = M.decode_step(CFG, params, toks, kc, vc, pos)[0]
        b = M.decode_step(CFG, params, toks, kc, vc, pos)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPrefillDecodeConsistency:
    def test_decode_continues_prefill(self, params):
        """Prefill P tokens, then decode token P; must equal prefilling P+1
        tokens directly (same attention, one step later)."""
        rng = np.random.default_rng(4)
        b, p = CFG.batch, CFG.prefill_bucket
        prompt = jnp.asarray(rng.integers(0, CFG.vocab, (b, p)), jnp.int32)
        lens = jnp.full((b,), p - 1, jnp.int32)

        # Path A: prefill p-1 tokens, decode token at position p-1.
        lgA, kpre, vpre = M.prefill_step(CFG, params, prompt, lens)
        next_tok = prompt[:, p - 1]
        kc = jnp.zeros(
            (CFG.n_layers, b, CFG.n_heads, CFG.ctx_bucket, CFG.head_dim),
            jnp.float32,
        )
        vc = jnp.zeros_like(kc)
        kc = kc.at[:, :, :, : p].set(kpre)
        vc = vc.at[:, :, :, : p].set(vpre)
        pos = jnp.full((b,), p - 1, jnp.int32)
        lgB, _, _ = M.decode_step(CFG, params, next_tok, kc, vc, pos)

        # Path B: prefill all p tokens; last-token logits.
        lens_full = jnp.full((b,), p, jnp.int32)
        lgC, _, _ = M.prefill_step(CFG, params, prompt, lens_full)
        np.testing.assert_allclose(lgB, lgC, atol=1e-3, rtol=1e-3)

    def test_prefill_padding_invariance(self, params):
        """Tokens beyond `lengths` must not affect last-token logits."""
        rng = np.random.default_rng(5)
        b, p = CFG.batch, CFG.prefill_bucket
        prompt = jnp.asarray(rng.integers(0, CFG.vocab, (b, p)), jnp.int32)
        lens = jnp.full((b,), p // 2, jnp.int32)
        lg1, k1, _ = M.prefill_step(CFG, params, prompt, lens)
        scrambled = prompt.at[:, p // 2 :].set(
            jnp.asarray(rng.integers(0, CFG.vocab, (b, p - p // 2)), jnp.int32)
        )
        lg2, k2, _ = M.prefill_step(CFG, params, scrambled, lens)
        np.testing.assert_allclose(lg1, lg2, atol=1e-5)
        # K rows inside the true length are identical too
        np.testing.assert_allclose(
            k1[:, :, :, : p // 2], k2[:, :, :, : p // 2], atol=1e-6
        )


class TestParamLayout:
    def test_param_order_matches_init(self):
        order = CFG.param_order()
        params = M.init_params(CFG, seed=0)
        assert len(order) == len(params)
        for (name, shape), w in zip(order, params):
            assert tuple(shape) == w.shape, name

    def test_param_count(self):
        total = sum(w.size for w in M.init_params(CFG))
        assert total == CFG.param_count()

    def test_init_deterministic(self):
        a = M.init_params(CFG, seed=0)
        b = M.init_params(CFG, seed=0)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_different_seeds_differ(self):
        a = M.init_params(CFG, seed=0)
        b = M.init_params(CFG, seed=1)
        assert any(not np.array_equal(x, y) for x, y in zip(a, b))


class TestVerifyStep:
    def test_output_shapes(self, params):
        rng = np.random.default_rng(7)
        kc, vc = _random_cache(rng, CFG)
        s = CFG.spec_bucket
        toks = jnp.asarray(
            rng.integers(0, CFG.vocab, (CFG.batch, s)), jnp.int32
        )
        pos = jnp.asarray([3, 9], jnp.int32)
        lg, nk, nv = M.verify_step(CFG, params, toks, kc, vc, pos)
        assert lg.shape == (CFG.batch, s, CFG.vocab)
        assert nk.shape == (
            CFG.n_layers,
            CFG.batch,
            CFG.n_heads,
            s,
            CFG.head_dim,
        )
        assert nv.shape == nk.shape

    def test_position_zero_matches_decode_step(self, params):
        """Row 0 of a verify pass is exactly one decode step: same kernel,
        same rescale fold — a pass whose drafts are all rejected reproduces
        plain decode."""
        rng = np.random.default_rng(8)
        kc, vc = _random_cache(rng, CFG)
        s = CFG.spec_bucket
        toks = jnp.asarray(
            rng.integers(0, CFG.vocab, (CFG.batch, s)), jnp.int32
        )
        pos = jnp.asarray([5, 17], jnp.int32)
        lg, nk, nv = M.verify_step(CFG, params, toks, kc, vc, pos)
        lg0, nk0, nv0 = M.decode_step(CFG, params, toks[:, 0], kc, vc, pos)
        np.testing.assert_allclose(lg[:, 0], lg0, atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(nk[:, :, :, 0], nk0, atol=5e-5, rtol=5e-5)
        np.testing.assert_allclose(nv[:, :, :, 0], nv0, atol=5e-5, rtol=5e-5)

    def test_matches_token_by_token_decode(self, params):
        """Verifying a block is the same function as decoding its tokens
        one at a time with the K/V rows appended to the cache — the
        associativity of the rescale operator, at model scale."""
        rng = np.random.default_rng(9)
        kc, vc = _random_cache(rng, CFG)
        s = CFG.spec_bucket
        toks = jnp.asarray(
            rng.integers(0, CFG.vocab, (CFG.batch, s)), jnp.int32
        )
        base = jnp.asarray([4, 11], jnp.int32)
        lg, nk, nv = M.verify_step(CFG, params, toks, kc, vc, base)

        kc_seq, vc_seq = np.asarray(kc), np.asarray(vc)
        for i in range(s):
            lg_i, nk_i, nv_i = M.decode_step(
                CFG,
                params,
                toks[:, i],
                jnp.asarray(kc_seq),
                jnp.asarray(vc_seq),
                base + i,
            )
            np.testing.assert_allclose(lg[:, i], lg_i, atol=2e-4, rtol=2e-4)
            for b in range(CFG.batch):
                p = int(base[b]) + i
                kc_seq[:, b, :, p, :] = np.asarray(nk_i)[:, b]
                vc_seq[:, b, :, p, :] = np.asarray(nv_i)[:, b]
