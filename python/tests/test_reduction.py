"""Property tests for the softmax re-scaling reduction (§IV-A) on the
jnp side: associativity, identity, chunk-subdivision exactness, and the
LeanTile table contract shared with the Rust planner."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import lean_attention as la
from compile.kernels import ref


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


class TestReductionProperties:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 64))
    def test_subdividing_a_partial_is_exact(self, seed, n):
        """Splitting any KV slice into sub-slices and reducing must give
        the same partial — the property the Rust executor's bucket
        chunking relies on."""
        rng = np.random.default_rng(seed)
        g, d = 2, 16
        q = _rand(rng, (g, d))
        k = _rand(rng, (g, n, d))
        v = _rand(rng, (g, n, d))
        valid = jnp.asarray(rng.integers(1, n + 1, g), jnp.int32)

        whole = ref.partial_attention_ref(q, k, v, valid)

        cut = int(rng.integers(1, n))
        p1 = ref.partial_attention_ref(q, k[:, :cut], v[:, :cut], jnp.minimum(valid, cut))
        p2 = ref.partial_attention_ref(
            q, k[:, cut:], v[:, cut:], jnp.clip(valid - cut, 0, n - cut)
        )
        o, m, l = ref.rescale_reduce_ref(*p1, *p2)

        # compare finalized outputs and rowsums
        np.testing.assert_allclose(
            ref.finalize_ref(o, jnp.where(l == 0, 1.0, l)),
            ref.finalize_ref(whole[0], jnp.where(whole[2] == 0, 1.0, whole[2])),
            atol=1e-5,
            rtol=1e-5,
        )

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_reduce_commutes_after_finalize(self, seed):
        rng = np.random.default_rng(seed)
        g, d, n = 3, 8, 48
        q = _rand(rng, (g, d))
        k = _rand(rng, (g, n, d))
        v = _rand(rng, (g, n, d))
        lens = jnp.full((g,), n, jnp.int32)
        px = ref.partial_attention_ref(q, k[:, :20], v[:, :20], jnp.minimum(lens, 20))
        py = ref.partial_attention_ref(q, k[:, 20:], v[:, 20:], lens - 20)
        oxy, _, lxy = ref.rescale_reduce_ref(*px, *py)
        oyx, _, lyx = ref.rescale_reduce_ref(*py, *px)
        np.testing.assert_allclose(
            ref.finalize_ref(oxy, lxy), ref.finalize_ref(oyx, lyx), atol=1e-6
        )

    def test_identity_is_neutral(self):
        rng = np.random.default_rng(0)
        g, d = 2, 8
        o = _rand(rng, (g, d))
        m = _rand(rng, (g, 1))
        l = jnp.abs(_rand(rng, (g, 1))) + 0.1
        ident = (jnp.zeros((g, d)), jnp.full((g, 1), ref.NEG_INF), jnp.zeros((g, 1)))
        o2, m2, l2 = ref.rescale_reduce_ref(o, m, l, *ident)
        np.testing.assert_allclose(o2, o, atol=1e-7)
        np.testing.assert_allclose(m2, m, atol=1e-7)
        np.testing.assert_allclose(l2, l, atol=1e-7)

    def test_reduction_stable_under_extreme_maxima(self):
        g, d = 1, 4
        parts = []
        for m in [-300.0, 250.0, -50.0, 249.0]:
            parts.append(
                (
                    jnp.ones((g, d)),
                    jnp.full((g, 1), m, jnp.float32),
                    jnp.ones((g, 1), jnp.float32),
                )
            )
        acc = parts[0]
        for p in parts[1:]:
            acc = ref.rescale_reduce_ref(*acc, *p)
        out = ref.finalize_ref(acc[0], acc[2])
        assert np.isfinite(np.asarray(out)).all()


class TestLeanTileTable:
    def test_paper_values(self):
        """§IV-B: 256 tokens for d=64, 128 for d=128 — and the Rust
        planner (partition::lean_tile) mirrors this table."""
        assert la.lean_tile_for(64) == 256
        assert la.lean_tile_for(128) == 128

    def test_fallback_positive(self):
        for d in [8, 48, 100, 512]:
            assert la.lean_tile_for(d) >= 16
