"""Make `pytest python/tests/` work from the repo root: the test modules
import the build-time package as `compile.*`, which lives here."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
