"""AOT pipeline: lower L2/L1 jax functions to HLO **text** artifacts.

``python -m compile.aot --out ../artifacts`` produces everything the Rust
runtime loads at startup:

* ``attn_full_g{G}_d{D}_c{C}.hlo.txt``    — exact decode attention (o, lse)
* ``attn_partial_g{G}_d{D}_c{C}.hlo.txt`` — un-scaled partials (o~, m, l)
* ``reduce_p{P}_g{G}_d{D}.hlo.txt``       — on-device rescale-reduce
* ``decode_{model}.hlo.txt`` / ``prefill_{model}.hlo.txt`` — transformer steps
* ``{model}.weights.bin``                 — flat little-endian f32 blob
* ``manifest.json``                       — shapes, buckets, param order

HLO *text* (not ``HloModuleProto.serialize``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Python runs only here — never on the Rust request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import lean_attention as la

# Attention artifact grid. G = batch*heads groups; every decode request the
# Rust engine forms is padded up to the nearest (G, C) bucket.
ATTN_BUCKETS = [
    # (g, d, ctx)
    (8, 64, 256),
    (8, 64, 1024),
    (32, 64, 256),
    (32, 64, 1024),
    (8, 128, 256),
    (16, 64, 4096),
]
REDUCE_BUCKETS = [
    # (p, g, d)
    (8, 8, 64),
    (8, 32, 64),
]
MODELS = ["tiny", "small"]


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: pathlib.Path, name: str, text: str) -> dict:
    path = out_dir / name
    path.write_text(text)
    return {
        "file": name,
        "bytes": len(text),
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }


def build_attention(out_dir: pathlib.Path) -> list[dict]:
    entries = []
    for g, d, ctx in ATTN_BUCKETS:
        tile = la.lean_tile_for(d)
        tile = min(tile, ctx)
        q = jax.ShapeDtypeStruct((g, d), jnp.float32)
        kv = jax.ShapeDtypeStruct((g, ctx, d), jnp.float32)
        lens = jax.ShapeDtypeStruct((g,), jnp.int32)

        full = jax.jit(
            lambda q, k, v, lens: la.decode_attention(q, k, v, lens)
        ).lower(q, kv, kv, lens)
        meta = _write(out_dir, f"attn_full_g{g}_d{d}_c{ctx}.hlo.txt", to_hlo_text(full))
        entries.append(
            {
                "kind": "full",
                "g": g,
                "d": d,
                "ctx": ctx,
                "tile": tile,
                "inputs": ["q[g,d]f32", "k[g,ctx,d]f32", "v[g,ctx,d]f32", "lens[g]i32"],
                "outputs": ["o[g,d]f32", "lse[g,1]f32"],
                **meta,
            }
        )

        part = jax.jit(
            lambda q, k, v, valid: la.partial_attention(q, k, v, valid)
        ).lower(q, kv, kv, lens)
        meta = _write(
            out_dir, f"attn_partial_g{g}_d{d}_c{ctx}.hlo.txt", to_hlo_text(part)
        )
        entries.append(
            {
                "kind": "partial",
                "g": g,
                "d": d,
                "ctx": ctx,
                "tile": tile,
                "inputs": ["q[g,d]f32", "k[g,ctx,d]f32", "v[g,ctx,d]f32", "valid[g]i32"],
                "outputs": ["o_unscaled[g,d]f32", "m[g,1]f32", "l[g,1]f32"],
                **meta,
            }
        )
    return entries


def build_reduce(out_dir: pathlib.Path) -> list[dict]:
    entries = []
    for p, g, d in REDUCE_BUCKETS:
        op = jax.ShapeDtypeStruct((p, g, d), jnp.float32)
        mp = jax.ShapeDtypeStruct((p, g, 1), jnp.float32)
        lowered = jax.jit(
            lambda o, m, l: la.rescale_reduce(o, m, l)
        ).lower(op, mp, mp)
        meta = _write(out_dir, f"reduce_p{p}_g{g}_d{d}.hlo.txt", to_hlo_text(lowered))
        entries.append(
            {
                "p": p,
                "g": g,
                "d": d,
                "inputs": ["o[p,g,d]f32", "m[p,g,1]f32", "l[p,g,1]f32"],
                "outputs": ["o[g,d]f32", "lse[g,1]f32"],
                **meta,
            }
        )
    return entries


def build_model(out_dir: pathlib.Path, name: str) -> dict:
    cfg = M.CONFIGS[name]
    params_np = M.init_params(cfg, seed=0)

    # Weights blob: flat little-endian f32 in param_order.
    blob = b"".join(np.ascontiguousarray(w, dtype="<f4").tobytes() for w in params_np)
    (out_dir / f"{name}.weights.bin").write_bytes(blob)

    l, b, h, c, dh = (
        cfg.n_layers,
        cfg.batch,
        cfg.n_heads,
        cfg.ctx_bucket,
        cfg.head_dim,
    )
    pspecs = [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in params_np]

    toks = jax.ShapeDtypeStruct((b,), jnp.int32)
    kcache = jax.ShapeDtypeStruct((l, b, h, c, dh), jnp.float32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)

    def dec(params, tokens, k_cache, v_cache, positions):
        return M.decode_step(cfg, params, tokens, k_cache, v_cache, positions)

    dec_meta = _write(
        out_dir,
        f"decode_{name}.hlo.txt",
        to_hlo_text(jax.jit(dec).lower(pspecs, toks, kcache, kcache, pos)),
    )

    ptoks = jax.ShapeDtypeStruct((b, cfg.prefill_bucket), jnp.int32)
    plens = jax.ShapeDtypeStruct((b,), jnp.int32)

    def pre(params, tokens, lengths):
        return M.prefill_step(cfg, params, tokens, lengths)

    pre_meta = _write(
        out_dir,
        f"prefill_{name}.hlo.txt",
        to_hlo_text(jax.jit(pre).lower(pspecs, ptoks, plens)),
    )

    # Speculative-decoding verify step: spec_bucket block tokens scored
    # per sequence in one pass, surfacing per-position logits.
    vtoks = jax.ShapeDtypeStruct((b, cfg.spec_bucket), jnp.int32)

    def ver(params, tokens, k_cache, v_cache, positions):
        return M.verify_step(cfg, params, tokens, k_cache, v_cache, positions)

    ver_meta = _write(
        out_dir,
        f"verify_{name}.hlo.txt",
        to_hlo_text(jax.jit(ver).lower(pspecs, vtoks, kcache, kcache, pos)),
    )
    ver_meta["spec_bucket"] = cfg.spec_bucket

    return {
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "ctx_bucket": cfg.ctx_bucket,
            "prefill_bucket": cfg.prefill_bucket,
            "batch": cfg.batch,
            "rope_base": cfg.rope_base,
            "param_count": cfg.param_count(),
        },
        "decode": dec_meta,
        "prefill": pre_meta,
        "verify": ver_meta,
        "weights": f"{name}.weights.bin",
        "weights_bytes": len(blob),
        "params": [
            {"name": n, "shape": list(s)} for n, s in cfg.param_order()
        ],
        "decode_inputs": "params... , tokens[b]i32, k_cache[l,b,h,c,dh]f32, "
        "v_cache[l,b,h,c,dh]f32, positions[b]i32",
        "decode_outputs": "logits[b,v]f32, new_k[l,b,h,dh]f32, new_v[l,b,h,dh]f32",
        "prefill_inputs": "params... , tokens[b,p]i32, lengths[b]i32",
        "prefill_outputs": "logits[b,v]f32, k[l,b,h,p,dh]f32, v[l,b,h,p,dh]f32",
        "verify_inputs": "params... , tokens[b,s]i32, k_cache[l,b,h,c,dh]f32, "
        "v_cache[l,b,h,c,dh]f32, positions[b]i32",
        "verify_outputs": "logits[b,s,v]f32, new_k[l,b,h,s,dh]f32, "
        "new_v[l,b,h,s,dh]f32",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--models", nargs="*", default=MODELS, help="model configs to build"
    )
    ap.add_argument(
        "--skip-models", action="store_true", help="attention artifacts only"
    )
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    t0 = time.time()
    manifest = {
        "version": 1,
        "generated_unix": int(t0),
        "jax": jax.__version__,
        "attention": build_attention(out_dir),
        "reduce": build_reduce(out_dir),
        "models": {},
    }
    print(f"attention+reduce artifacts: {time.time() - t0:.1f}s")

    if not args.skip_models:
        for name in args.models:
            t = time.time()
            manifest["models"][name] = build_model(out_dir, name)
            print(f"model {name}: {time.time() - t:.1f}s")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    n = len(manifest["attention"]) + len(manifest["reduce"]) + 3 * len(
        manifest["models"]
    )
    print(f"wrote {n} HLO artifacts + manifest to {out_dir} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
