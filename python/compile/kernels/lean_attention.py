"""L1 — LeanAttention Pallas kernels (decode phase).

Three kernels, all built from one online-softmax core:

* ``decode_attention``  — exact length-masked decode attention over the
  whole (bucketed) context. LeanTile-sized KV blocks stream through VMEM
  while ``(acc, m, l)`` stay resident; the output block doubles as the
  accumulator (the classic revisit-the-same-block carry). Equivalent to
  Algorithm 1 run start-to-finish by a single CTA.
* ``partial_attention`` — Algorithm 1 proper: the *un-scaled* partial
  output ``(O~, m, l)`` over one KV slice. This is what a LeanAttention
  CTA computes before the host-block reduction; the Rust coordinator
  executes this artifact once per stream-K work assignment and performs
  the softmax re-scaling reduction itself (Alg 2 lines 24-39).
* ``rescale_reduce``    — the reduction as a kernel, for when the whole
  reduce should stay on-device: folds ``P`` partials into one output.

TPU adaptation of the paper's CUDA design (DESIGN.md §Hardware-Adaptation):
CUDA shared-memory KV tiles become VMEM blocks expressed via ``BlockSpec``;
the warp-level online softmax becomes vectorized ``rowmax/rowsum`` feeding
``[q, d] x [d, T]`` MXU matmuls with fp32 accumulation; CTA scheduling
(the stream-K placement) moves to the Rust coordinator. Kernels run with
``interpret=True`` — real-TPU lowering emits Mosaic custom-calls the CPU
PJRT plugin cannot execute; see DESIGN.md for the VMEM/MXU estimates used
for performance reasoning instead.

Shared conventions: ``G = batch*heads`` flattened groups, ``q: [G, d]``,
``k/v: [G, N, d]``, per-group valid lengths ``[G, 1] int32``. Outputs are
always f32 (accumulation dtype) regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Finite -inf stand-in (see ref.NEG_INF): keeps exp NaN-free when a whole
# LeanTile is masked while still underflowing to exactly 0.
NEG_INF = -1.0e30

# LeanTile granularity table (§IV-B): empirically optimal KV-block token
# counts per head dimension on A100-class hardware. Used as the default
# block size along N_k, and mirrored by the Rust partitioner
# (partition::lean_tile).
LEAN_TILE_BY_HEAD_DIM = {32: 256, 64: 256, 96: 128, 128: 128, 256: 64}


def lean_tile_for(head_dim: int) -> int:
    """Smallest profitable KV-block size for ``head_dim`` (§IV-B)."""
    if head_dim in LEAN_TILE_BY_HEAD_DIM:
        return LEAN_TILE_BY_HEAD_DIM[head_dim]
    # Fall back to keeping the K+V tile footprint ~constant (2*T*d*4B).
    return max(16, (256 * 64) // max(head_dim, 1))


def _online_softmax_kernel(
    len_ref,  # [Gb, 1] int32 valid length per group in this block
    q_ref,  # [Gb, d]
    k_ref,  # [Gb, T, d]
    v_ref,  # [Gb, T, d]
    o_ref,  # [Gb, d] f32 — doubles as the accumulator across KV blocks
    m_ref,  # [Gb, 1] f32 running rowmax
    l_ref,  # [Gb, 1] f32 running rowsum
    *,
    scale: float,
    block_t: int,
    normalize: bool,
):
    """One LeanTile iteration of Algorithm 1 (lines 13-26), batched over a
    block of ``Gb`` groups.

    Grid is (num_group_blocks, num_kv_blocks); the KV axis is innermost so
    (o, m, l) blocks stay resident while j sweeps the context. Group
    batching was the perf-pass change (EXPERIMENTS.md §Perf L1): one grid
    step now does `[Gb, T] x [T, d]` contractions instead of `[1, T]`,
    13x faster under interpret mode and MXU-shaped on real TPU.
    ``normalize=True`` additionally applies Alg 2 line 38 on the last
    block; ``normalize=False`` leaves the un-scaled partial (the
    LeanAttention CTA contract).
    """
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)  # [Gb, d]
    k = k_ref[...].astype(jnp.float32)  # [Gb, T, d]
    v = v_ref[...].astype(jnp.float32)

    s = (
        jnp.einsum("gd,gtd->gt", q, k, preferred_element_type=jnp.float32)
        * scale
    )  # [Gb, T]

    # Length masking: absolute position of column t is j*T + t.
    pos = j * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    in_range = pos < len_ref[...]
    s = jnp.where(in_range, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # Fully-masked tiles keep m_new == NEG_INF, making s - m_new == 0 and
    # p == 1 on every (masked) column; zero them explicitly.
    p = jnp.where(in_range, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = alpha * o_ref[...] + jnp.einsum(
        "gt,gtd->gd", p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    if normalize:

        @pl.when(j == nj - 1)
        def _fin():
            # Guard l == 0 (length 0 — not produced by the engine, but the
            # kernel should not emit NaN for padding groups).
            l = l_ref[...]
            o_ref[...] = o_ref[...] / jnp.where(l == 0.0, 1.0, l)


def _attention_call(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale: float | None,
    block_t: int | None,
    normalize: bool,
    interpret: bool,
):
    g, d = q.shape
    n = k.shape[1]
    scale = (1.0 / d**0.5) if scale is None else scale
    block_t = lean_tile_for(d) if block_t is None else block_t
    block_t = min(block_t, n)
    if n % block_t != 0:
        raise ValueError(f"context bucket {n} not a multiple of LeanTile {block_t}")
    lengths = lengths.reshape(g, 1).astype(jnp.int32)

    # Group-block size: batch as many groups per grid step as the VMEM
    # budget allows (K+V blocks are 2*Gb*T*d*4B; cap ~8 MiB), while
    # keeping Gb a divisor of g so blocks tile exactly.
    vmem_cap_groups = max(1, (8 << 20) // (2 * block_t * d * 4))
    block_g = g
    if g > vmem_cap_groups:
        block_g = next(
            (c for c in range(min(g, vmem_cap_groups), 0, -1) if g % c == 0),
            1,
        )

    grid = (g // block_g, n // block_t)
    kernel = functools.partial(
        _online_softmax_kernel,
        scale=scale,
        block_t=block_t,
        normalize=normalize,
    )
    out_shapes = (
        jax.ShapeDtypeStruct((g, d), jnp.float32),  # O (or O~)
        jax.ShapeDtypeStruct((g, 1), jnp.float32),  # m
        jax.ShapeDtypeStruct((g, 1), jnp.float32),  # l
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_g, 1), lambda g_, j: (g_, 0)),  # lengths
            pl.BlockSpec((block_g, d), lambda g_, j: (g_, 0)),  # q
            pl.BlockSpec((block_g, block_t, d), lambda g_, j: (g_, j, 0)),  # k
            pl.BlockSpec((block_g, block_t, d), lambda g_, j: (g_, j, 0)),  # v
        ],
        out_specs=(
            pl.BlockSpec((block_g, d), lambda g_, j: (g_, 0)),
            pl.BlockSpec((block_g, 1), lambda g_, j: (g_, 0)),
            pl.BlockSpec((block_g, 1), lambda g_, j: (g_, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(lengths, q, k, v)
    return o, m, l


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    scale: float | None = None,
    block_t: int | None = None,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact decode attention. Returns ``(O [G,d] f32, L [G,1] logsumexp)``.

    ``L = m + log(l)`` is emitted like FlashAttention-2 (Alg 2 line 39) so
    downstream consumers (e.g. a backward pass or a cross-device reduce)
    can re-scale this output against others.
    """
    o, m, l = _attention_call(
        q, k, v, lengths, scale=scale, block_t=block_t, normalize=True,
        interpret=interpret,
    )
    lse = m + jnp.log(jnp.where(l == 0.0, 1.0, l))
    return o, lse


def partial_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    valid: jnp.ndarray,
    scale: float | None = None,
    block_t: int | None = None,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Un-scaled partial attention over a KV slice: ``(O~, m, l)``.

    One LeanAttention work assignment (Alg 1 called from Alg 2 line 16).
    ``k/v: [G, S, d]`` is a slice of the context; ``valid: [G]`` gives the
    number of real rows per group. The kernel's q-block view never sees
    the head boundary — the Rust stream-K planner decides which slices
    exist and how their partials reduce.
    """
    return _attention_call(
        q, k, v, valid, scale=scale, block_t=block_t, normalize=False,
        interpret=interpret,
    )


def _rescale_reduce_kernel(op_ref, mp_ref, lp_ref, o_ref, m_ref, l_ref):
    """Fold partial i into the running (o, m, l) — Alg 2 lines 29-35.

    Batched over all G groups per grid step (perf pass, EXPERIMENTS.md
    §Perf L1): grid is just the partial axis.
    """
    i = pl.program_id(0)
    ni = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_i = mp_ref[0, ...]
    l_i = lp_ref[0, ...]
    o_i = op_ref[0, ...]
    m_new = jnp.maximum(m_ref[...], m_i)
    a_acc = jnp.exp(m_ref[...] - m_new)
    a_i = jnp.exp(m_i - m_new)
    l_ref[...] = a_acc * l_ref[...] + a_i * l_i
    o_ref[...] = a_acc * o_ref[...] + a_i * o_i
    m_ref[...] = m_new

    @pl.when(i == ni - 1)
    def _fin():
        l = l_ref[...]
        o_ref[...] = o_ref[...] / jnp.where(l == 0.0, 1.0, l)


def rescale_reduce(
    o_parts: jnp.ndarray,  # [P, G, d] f32
    m_parts: jnp.ndarray,  # [P, G, 1] f32
    l_parts: jnp.ndarray,  # [P, G, 1] f32
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reduce P partials per group into the exact output. Returns (O, lse).

    The host-block reduction (Alg 2 lines 24-39) as an on-device kernel.
    Empty partials are the identity element ``(0, NEG_INF, 0)``, so padded
    P-slots are harmless.
    """
    p, g, d = o_parts.shape
    o, m, l = pl.pallas_call(
        _rescale_reduce_kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((g, d), lambda i: (0, 0)),
            pl.BlockSpec((g, 1), lambda i: (0, 0)),
            pl.BlockSpec((g, 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((g, d), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ),
        interpret=interpret,
    )(o_parts, m_parts, l_parts)
    lse = m + jnp.log(jnp.where(l == 0.0, 1.0, l))
    return o, lse
