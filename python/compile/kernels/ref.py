"""Pure-jnp correctness oracles for LeanAttention.

Everything the Pallas kernels (and the Rust reduction path) compute is
checked against these functions:

* ``attention_ref``        — exact length-masked decode attention.
* ``partial_attention_ref``— the un-scaled partial output ``(O~, m, l)``
                             of §IV-A computed over one KV slice.
* ``rescale_reduce_ref``   — the softmax re-scaling reduction operator
                             ``f(x, y)`` of §IV-A (pairwise).
* ``finalize_ref``         — ``O = diag(l)^-1 O~`` (Alg 2 line 38).
* ``lean_attention_ref``   — full stream-K-style split → partial →
                             tree-reduce pipeline; must equal
                             ``attention_ref`` for *any* split and any
                             association order (the paper's associativity
                             theorem).

Shapes use the flattened-group convention the whole repo shares:
``G = batch * heads``, ``q: [G, d]``, ``k/v: [G, N, d]``.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

# Finite stand-in for -inf: keeps ``exp(s - m)`` NaN-free when an entire
# KV block is masked out (see kernel docstring). exp(-1e30 - m) underflows
# to exactly 0.0 for any realistic m, so results match true -inf masking.
NEG_INF = -1.0e30


def _mask_scores(s: jnp.ndarray, start: int, valid: jnp.ndarray) -> jnp.ndarray:
    """Mask score columns at absolute positions >= valid.

    ``s: [G, N]`` holds scores for absolute KV positions
    ``start .. start+N``; ``valid: [G]`` is the per-group context length.
    """
    n = s.shape[-1]
    pos = start + jnp.arange(n, dtype=jnp.int32)[None, :]
    return jnp.where(pos < valid[:, None], s, NEG_INF)


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    scale: float | None = None,
) -> jnp.ndarray:
    """Exact decode attention. q:[G,d] k,v:[G,N,d] lengths:[G] -> [G,d]."""
    d = q.shape[-1]
    scale = (1.0 / d**0.5) if scale is None else scale
    q32 = q.astype(jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    s = jnp.einsum("gd,gnd->gn", q32, k32) * scale
    s = _mask_scores(s, 0, lengths.astype(jnp.int32))
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("gn,gnd->gd", p / l, v32)


def partial_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    valid: jnp.ndarray,
    scale: float | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Un-scaled partial attention over one KV slice (§IV-A, first part).

    ``k/v: [G, S, d]`` is the slice, ``valid: [G]`` the number of its rows
    that are real tokens (the rest are padding). Returns
    ``(O~: [G, d], m: [G, 1], l: [G, 1])``.
    """
    d = q.shape[-1]
    scale = (1.0 / d**0.5) if scale is None else scale
    q32 = q.astype(jnp.float32)
    s = jnp.einsum("gd,gnd->gn", q32, k.astype(jnp.float32)) * scale
    s = _mask_scores(s, 0, valid.astype(jnp.int32))
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    # A fully-masked slice must contribute zero weight: zero p explicitly so
    # exp(NEG_INF - NEG_INF) = 1 rows cannot leak in.
    p = jnp.where(
        (jnp.arange(s.shape[-1], dtype=jnp.int32)[None, :] < valid[:, None]),
        p,
        0.0,
    )
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("gn,gnd->gd", p, v.astype(jnp.float32))
    return o, m, l


def rescale_reduce_ref(
    ox: jnp.ndarray,
    mx: jnp.ndarray,
    lx: jnp.ndarray,
    oy: jnp.ndarray,
    my: jnp.ndarray,
    ly: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Softmax re-scaling operator f(x, y) of §IV-A. All-f32, pairwise."""
    m = jnp.maximum(mx, my)
    ax = jnp.exp(mx - m)
    ay = jnp.exp(my - m)
    l = ax * lx + ay * ly
    o = ax * ox + ay * oy
    return o, m, l


def finalize_ref(o: jnp.ndarray, l: jnp.ndarray) -> jnp.ndarray:
    """O = diag(l)^-1 O~ (Alg 2 line 38)."""
    return o / l


def split_points_to_slices(splits: Sequence[int], n: int) -> list[tuple[int, int]]:
    """[s0, s1, ...] interior split points -> [(lo, hi), ...] covering [0, n)."""
    bounds = [0, *sorted(set(int(s) for s in splits if 0 < s < n)), n]
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


def lean_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    splits: Sequence[int],
    reduce_order: str = "left",
    scale: float | None = None,
) -> jnp.ndarray:
    """Full LeanAttention pipeline in jnp: arbitrary unequal splits of the
    context, partial attention per slice, reduction in the requested
    association order, then finalize. The associativity theorem says this
    equals ``attention_ref`` for every ``splits`` and ``reduce_order``.

    reduce_order: 'left' (((x,y),z)…), 'right' (x,(y,(z…))), or 'tree'.
    """
    n = k.shape[1]
    slices = split_points_to_slices(splits, n)
    parts = []
    for lo, hi in slices:
        valid = jnp.clip(lengths.astype(jnp.int32) - lo, 0, hi - lo)
        parts.append(
            partial_attention_ref(q, k[:, lo:hi], v[:, lo:hi], valid, scale=scale)
        )

    def red(a, b):
        return rescale_reduce_ref(*a, *b)

    if reduce_order == "left":
        acc = parts[0]
        for p in parts[1:]:
            acc = red(acc, p)
    elif reduce_order == "right":
        acc = parts[-1]
        for p in reversed(parts[:-1]):
            acc = red(p, acc)
    elif reduce_order == "tree":
        level = parts
        while len(level) > 1:
            nxt = [
                red(level[i], level[i + 1]) if i + 1 < len(level) else level[i]
                for i in range(0, len(level), 2)
            ]
            level = nxt
        acc = level[0]
    else:  # pragma: no cover - guarded by tests
        raise ValueError(f"unknown reduce_order {reduce_order!r}")
    o, _, l = acc
    return finalize_ref(o, l)


def mha_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
) -> jnp.ndarray:
    """Batched multi-head wrapper: q [B,H,d], k/v [B,H,N,d], lengths [B]."""
    b, h, d = q.shape
    g = b * h
    glens = jnp.repeat(lengths, h)
    o = attention_ref(
        q.reshape(g, d), k.reshape(g, -1, d), v.reshape(g, -1, d), glens
    )
    return o.reshape(b, h, d)
