"""L2 — JAX transformer model (build-time only; never on the request path).

A small decoder-only transformer (pre-LN, RoPE, GELU MLP, tied LM head)
whose *decode step* routes its attention through the L1 LeanAttention
Pallas kernel. Two entry points are AOT-lowered by ``compile.aot``:

* ``prefill_step``  — causal self-attention over the whole prompt,
  producing the last-token logits plus the K/V cache the decode phase
  consumes (the paper's prefill/decode split, §I).
* ``decode_step``   — one autoregressive step: N_q = 1 per sequence,
  attention over the bucketed KV cache via ``kernels.lean_attention``.
  Returns logits and the current token's per-layer K/V rows so the Rust
  coordinator can append them to its paged cache (the cache lives in
  Rust; the graph is pure).
* ``verify_step``   — the speculative-decoding verify pass: N_q =
  ``spec_bucket`` block tokens per sequence (pending token + drafts),
  causal within the block, scored against the cache in one pass.
  Returns **per-position** logits plus the whole block's K/V rows.

Weight layout is a flat ordered list (see ``param_order``) so the Rust
runtime can feed the blob ``compile.aot`` serializes without pytree
machinery.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import lean_attention as la
from compile.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyper-parameters.

    ``name`` doubles as the artifact key. ``ctx_bucket`` is the static KV
    bucket the decode artifact is compiled for (lengths are masked inside
    the kernel); ``prefill_bucket`` likewise for the prompt.
    """

    name: str = "tiny"
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    ctx_bucket: int = 256
    prefill_bucket: int = 64
    batch: int = 2
    rope_base: float = 10000.0
    # Draft-block tokens the verify step scores per sequence (pending
    # token + spec_bucket-1 drafts) — the speculative-decoding window.
    spec_bucket: int = 4

    @property
    def groups(self) -> int:
        return self.batch * self.n_heads

    def param_order(self) -> list[tuple[str, tuple[int, ...]]]:
        """Flat (name, shape) list defining blob order for the Rust loader."""
        d, h, dh, f = self.d_model, self.n_heads, self.head_dim, self.d_ff
        order: list[tuple[str, tuple[int, ...]]] = [
            ("embed", (self.vocab, d)),
        ]
        for i in range(self.n_layers):
            order += [
                (f"l{i}.ln1.scale", (d,)),
                (f"l{i}.ln1.bias", (d,)),
                (f"l{i}.wq", (d, h * dh)),
                (f"l{i}.wk", (d, h * dh)),
                (f"l{i}.wv", (d, h * dh)),
                (f"l{i}.wo", (h * dh, d)),
                (f"l{i}.ln2.scale", (d,)),
                (f"l{i}.ln2.bias", (d,)),
                (f"l{i}.w1", (d, f)),
                (f"l{i}.b1", (f,)),
                (f"l{i}.w2", (f, d)),
                (f"l{i}.b2", (d,)),
            ]
        order += [("ln_f.scale", (d,)), ("ln_f.bias", (d,))]
        return order

    def param_count(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.param_order())


# Registry of configs the AOT pipeline knows how to build. "tiny" keeps
# `make artifacts` fast; "small" is the e2e serving demo scale.
CONFIGS: dict[str, ModelConfig] = {
    "tiny": ModelConfig(),
    "small": ModelConfig(
        name="small",
        vocab=2048,
        d_model=256,
        n_layers=4,
        n_heads=4,
        head_dim=64,
        d_ff=1024,
        ctx_bucket=512,
        prefill_bucket=128,
        batch=4,
    ),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> list[np.ndarray]:
    """Deterministic scaled-normal init, in ``param_order``."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in cfg.param_order():
        if name.endswith((".scale",)):
            w = np.ones(shape, dtype=np.float32)
        elif name.endswith((".bias", ".b1", ".b2")):
            w = np.zeros(shape, dtype=np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / math.sqrt(fan_in)
            w = rng.standard_normal(shape).astype(np.float32) * std
        out.append(w)
    return out


def _layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * scale + bias


def _rope_freqs(cfg: ModelConfig, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for ``positions`` [...]-shaped int32 -> [..., dh/2]."""
    half = cfg.head_dim // 2
    inv = cfg.rope_base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x1, x2) -> (x1 cos - x2 sin, x1 sin + x2 cos).

    ``x: [..., dh]``; cos/sin broadcast over leading dims with a [..., dh/2]
    trailing shape.
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _unpack(cfg: ModelConfig, params: Sequence[jnp.ndarray]) -> dict[str, jnp.ndarray]:
    return {name: p for (name, _), p in zip(cfg.param_order(), params)}


def decode_step(
    cfg: ModelConfig,
    params: Sequence[jnp.ndarray],
    tokens: jnp.ndarray,  # [B] int32 current token per sequence
    k_cache: jnp.ndarray,  # [L, B, H, C, dh] f32 (C = ctx_bucket)
    v_cache: jnp.ndarray,  # [L, B, H, C, dh]
    positions: jnp.ndarray,  # [B] int32 index of `tokens` in each sequence
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step. Returns (logits [B,V], new_k [L,B,H,dh], new_v).

    The current token's K/V are *not* written into the cache tensors here —
    attention folds them in as an extra partial via the softmax re-scaling
    operator (exactly the paper's reduction, applied once more for the
    freshest token), and the Rust coordinator persists ``new_k/new_v`` into
    its paged cache for subsequent steps. This keeps the graph free of
    scatter ops and the cache single-writer (Rust).
    """
    p = _unpack(cfg, params)
    b, h, dh = cfg.batch, cfg.n_heads, cfg.head_dim
    g = b * h

    x = p["embed"][tokens]  # [B, D]
    cos, sin = _rope_freqs(cfg, positions)  # [B, dh/2]

    new_ks, new_vs = [], []
    for i in range(cfg.n_layers):
        hpre = _layer_norm(x, p[f"l{i}.ln1.scale"], p[f"l{i}.ln1.bias"])
        q = (hpre @ p[f"l{i}.wq"]).reshape(b, h, dh)
        k_new = (hpre @ p[f"l{i}.wk"]).reshape(b, h, dh)
        v_new = (hpre @ p[f"l{i}.wv"]).reshape(b, h, dh)
        q = _apply_rope(q, cos[:, None, :], sin[:, None, :])
        k_new = _apply_rope(k_new, cos[:, None, :], sin[:, None, :])
        new_ks.append(k_new)
        new_vs.append(v_new)

        # Cached-context attention through the L1 Pallas kernel.
        glens = jnp.repeat(positions, h)  # cache holds `positions` tokens
        o_c, m_c, l_c = la.partial_attention(
            q.reshape(g, dh),
            k_cache[i].reshape(g, cfg.ctx_bucket, dh),
            v_cache[i].reshape(g, cfg.ctx_bucket, dh),
            glens,
        )
        # Fresh-token partial (a 1-token slice), folded in by re-scaling.
        o_n, m_n, l_n = kref.partial_attention_ref(
            q.reshape(g, dh),
            k_new.reshape(g, 1, dh),
            v_new.reshape(g, 1, dh),
            jnp.ones((g,), jnp.int32),
        )
        o, _, l = kref.rescale_reduce_ref(o_c, m_c, l_c, o_n, m_n, l_n)
        attn = kref.finalize_ref(o, l).reshape(b, h * dh)
        x = x + attn @ p[f"l{i}.wo"]

        hpre2 = _layer_norm(x, p[f"l{i}.ln2.scale"], p[f"l{i}.ln2.bias"])
        ff = jax.nn.gelu(hpre2 @ p[f"l{i}.w1"] + p[f"l{i}.b1"])
        x = x + ff @ p[f"l{i}.w2"] + p[f"l{i}.b2"]

    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    logits = x @ p["embed"].T  # tied head
    new_k = jnp.stack(new_ks)  # [L, B, H, dh]
    new_v = jnp.stack(new_vs)
    return logits, new_k, new_v


def verify_step(
    cfg: ModelConfig,
    params: Sequence[jnp.ndarray],
    tokens: jnp.ndarray,  # [B, S] int32: pending token + S-1 drafted tokens
    k_cache: jnp.ndarray,  # [L, B, H, C, dh] f32
    v_cache: jnp.ndarray,  # [L, B, H, C, dh]
    positions: jnp.ndarray,  # [B] int32 cached tokens per sequence
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Multi-token verify step (speculative decoding).

    Scores all ``S = spec_bucket`` draft-block tokens of every sequence
    in one pass: position ``s`` attends to the ``positions`` cached
    tokens (through the L1 Pallas kernel) plus block tokens ``0..=s``
    (a rescale-folded reference partial — causal within the block).
    Returns ``(logits [B, S, V], new_k [L, B, H, S, dh], new_v [...])``.

    Position 0 is exactly ``decode_step``'s computation (same kernel,
    same fold), so a pass whose drafts are all rejected reproduces the
    plain decode step; later positions extend the fresh partial to the
    block slice, exact by the associativity of the §IV-A operator.
    Verifying k drafts therefore turns k memory-bound single-query
    steps into one walk of the cached KV stream serving k+1 query rows
    — the arithmetic-intensity shift LeanAttention's stream-K
    decomposition is built to schedule.
    """
    p = _unpack(cfg, params)
    b, s_len, h, dh = cfg.batch, tokens.shape[1], cfg.n_heads, cfg.head_dim
    g = b * h

    x = p["embed"][tokens]  # [B, S, D]
    pos = positions[:, None] + jnp.arange(s_len, dtype=jnp.int32)[None, :]
    cos, sin = _rope_freqs(cfg, pos)  # [B, S, dh/2]

    new_ks, new_vs = [], []
    for i in range(cfg.n_layers):
        hpre = _layer_norm(x, p[f"l{i}.ln1.scale"], p[f"l{i}.ln1.bias"])
        q = (hpre @ p[f"l{i}.wq"]).reshape(b, s_len, h, dh)
        k_new = (hpre @ p[f"l{i}.wk"]).reshape(b, s_len, h, dh)
        v_new = (hpre @ p[f"l{i}.wv"]).reshape(b, s_len, h, dh)
        q = _apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
        k_new = _apply_rope(k_new, cos[:, :, None, :], sin[:, :, None, :])
        k_bh = jnp.moveaxis(k_new, 2, 1)  # [B, H, S, dh]
        v_bh = jnp.moveaxis(v_new, 2, 1)
        new_ks.append(k_bh)
        new_vs.append(v_bh)

        # Cached-context partial once per block position (one KV walk
        # per position here on the build-time CPU path; the Rust
        # multi-query planner is what schedules the shared walk on the
        # modeled GPU), folded with the causal in-block partial.
        glens = jnp.repeat(positions, h)
        outs = []
        for s in range(s_len):
            q_s = q[:, s].reshape(g, dh)
            o_c, m_c, l_c = la.partial_attention(
                q_s,
                k_cache[i].reshape(g, cfg.ctx_bucket, dh),
                v_cache[i].reshape(g, cfg.ctx_bucket, dh),
                glens,
            )
            o_n, m_n, l_n = kref.partial_attention_ref(
                q_s,
                k_bh.reshape(g, s_len, dh),
                v_bh.reshape(g, s_len, dh),
                jnp.full((g,), s + 1, jnp.int32),
            )
            o, _, l = kref.rescale_reduce_ref(o_c, m_c, l_c, o_n, m_n, l_n)
            outs.append(kref.finalize_ref(o, l).reshape(b, h * dh))
        attn = jnp.stack(outs, axis=1)  # [B, S, H*dh]
        x = x + attn @ p[f"l{i}.wo"]

        hpre2 = _layer_norm(x, p[f"l{i}.ln2.scale"], p[f"l{i}.ln2.bias"])
        ff = jax.nn.gelu(hpre2 @ p[f"l{i}.w1"] + p[f"l{i}.b1"])
        x = x + ff @ p[f"l{i}.w2"] + p[f"l{i}.b2"]

    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    logits = x @ p["embed"].T  # [B, S, V]
    new_k = jnp.stack(new_ks)  # [L, B, H, S, dh]
    new_v = jnp.stack(new_vs)
    return logits, new_k, new_v


def prefill_step(
    cfg: ModelConfig,
    params: Sequence[jnp.ndarray],
    tokens: jnp.ndarray,  # [B, P] int32, right-padded
    lengths: jnp.ndarray,  # [B] int32 true prompt lengths
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prompt prefill: returns (last_logits [B,V], k [L,B,H,P,dh], v [...]).

    Plain causal jnp attention — prefill parallelism is not this paper's
    contribution (§III-A); FlashAttention-2 already serves it well.
    """
    p = _unpack(cfg, params)
    b, pl_, h, dh = cfg.batch, tokens.shape[1], cfg.n_heads, cfg.head_dim

    x = p["embed"][tokens]  # [B, P, D]
    pos = jnp.arange(pl_, dtype=jnp.int32)
    cos, sin = _rope_freqs(cfg, pos)  # [P, dh/2]

    causal = pos[None, :] <= pos[:, None]  # [P, P]
    in_len = pos[None, None, :] < lengths[:, None, None]  # [B, 1, P]
    mask = causal[None] & in_len  # [B, P, P]

    ks, vs = [], []
    for i in range(cfg.n_layers):
        hpre = _layer_norm(x, p[f"l{i}.ln1.scale"], p[f"l{i}.ln1.bias"])
        q = (hpre @ p[f"l{i}.wq"]).reshape(b, pl_, h, dh)
        k = (hpre @ p[f"l{i}.wk"]).reshape(b, pl_, h, dh)
        v = (hpre @ p[f"l{i}.wv"]).reshape(b, pl_, h, dh)
        q = _apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = _apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
        k_bh = jnp.moveaxis(k, 2, 1)  # [B, H, P, dh]
        v_bh = jnp.moveaxis(v, 2, 1)
        ks.append(k_bh)
        vs.append(v_bh)

        s = jnp.einsum("bqhd,bhkd->bhqk", q, k_bh) / math.sqrt(dh)
        s = jnp.where(mask[:, None, :, :], s, kref.NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bqhd", w, v_bh).reshape(b, pl_, h * dh)
        x = x + attn @ p[f"l{i}.wo"]

        hpre2 = _layer_norm(x, p[f"l{i}.ln2.scale"], p[f"l{i}.ln2.bias"])
        ff = jax.nn.gelu(hpre2 @ p[f"l{i}.w1"] + p[f"l{i}.b1"])
        x = x + ff @ p[f"l{i}.w2"] + p[f"l{i}.b2"]

    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    # Logits of each sequence's *last real* token.
    last = jnp.clip(lengths - 1, 0, pl_ - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    logits = x_last @ p["embed"].T
    k_all = jnp.stack(ks)  # [L, B, H, P, dh]
    v_all = jnp.stack(vs)
    return logits, k_all, v_all


def decode_step_dense(
    cfg: ModelConfig,
    params: Sequence[jnp.ndarray],
    tokens: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    positions: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle decode step: identical math via the pure-jnp reference
    attention (no Pallas). Used by tests to pin ``decode_step``."""
    p = _unpack(cfg, params)
    b, h, dh = cfg.batch, cfg.n_heads, cfg.head_dim
    g = b * h

    x = p["embed"][tokens]
    cos, sin = _rope_freqs(cfg, positions)

    new_ks, new_vs = [], []
    for i in range(cfg.n_layers):
        hpre = _layer_norm(x, p[f"l{i}.ln1.scale"], p[f"l{i}.ln1.bias"])
        q = (hpre @ p[f"l{i}.wq"]).reshape(b, h, dh)
        k_new = (hpre @ p[f"l{i}.wk"]).reshape(b, h, dh)
        v_new = (hpre @ p[f"l{i}.wv"]).reshape(b, h, dh)
        q = _apply_rope(q, cos[:, None, :], sin[:, None, :])
        k_new = _apply_rope(k_new, cos[:, None, :], sin[:, None, :])
        new_ks.append(k_new)
        new_vs.append(v_new)

        # Concatenate fresh token behind the (bucketed) cache, then mask by
        # true length with the fresh token mapped to slot `positions`.
        kc = k_cache[i].reshape(g, cfg.ctx_bucket, dh)
        vc = v_cache[i].reshape(g, cfg.ctx_bucket, dh)
        glens = jnp.repeat(positions, h)
        # scatter fresh kv into slot glens (per group)
        idx = glens[:, None, None]
        kn = k_new.reshape(g, 1, dh)
        vn = v_new.reshape(g, 1, dh)
        onehot = (
            jnp.arange(cfg.ctx_bucket, dtype=jnp.int32)[None, :, None] == idx
        )
        kc = jnp.where(onehot, kn, kc)
        vc = jnp.where(onehot, vn, vc)
        attn = kref.attention_ref(q.reshape(g, dh), kc, vc, glens + 1)
        x = x + attn.reshape(b, h * dh) @ p[f"l{i}.wo"]

        hpre2 = _layer_norm(x, p[f"l{i}.ln2.scale"], p[f"l{i}.ln2.bias"])
        ff = jax.nn.gelu(hpre2 @ p[f"l{i}.w1"] + p[f"l{i}.b1"])
        x = x + ff @ p[f"l{i}.w2"] + p[f"l{i}.b2"]

    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    logits = x @ p["embed"].T
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)
