//! Shared-prefix serving: one system prompt, many users.
//!
//! The dominant production traffic pattern — millions of requests that
//! all start with the same system prompt — turns into three wins here:
//!
//! 1. **Storage**: the radix prefix index + refcounted paged KV cache
//!    keep ONE copy of the shared prefix (part 2).
//! 2. **Bandwidth**: the cascade plan streams the shared prefix KV once
//!    per decode step for the whole group instead of once per sequence
//!    (part 1, simulator).
//! 3. **Serving**: the engine wires both into admission + metrics
//!    (part 3, requires `make artifacts`; skipped gracefully otherwise).
//!
//! ```sh
//! cargo run --release --example shared_prefix
//! ```

use std::rc::Rc;

use lean_attention::coordinator::{
    Engine, EngineConfig, Metrics, PagedKvCache, RadixPrefixIndex,
};
use lean_attention::partition::cascade::{CascadeProblem, PrefixGroup};
use lean_attention::partition::plan::Strategy;
use lean_attention::runtime::{Manifest, Runtime};
use lean_attention::sim::cascade::simulate_cascade;
use lean_attention::sim::schedule::simulate;
use lean_attention::sim::GpuArch;
use lean_attention::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- part 1: the bandwidth argument on the A100 model ----------------
    println!("== cascade decode vs flat stream-K (A100, 32 heads, shared 64k system prompt) ==");
    println!(
        "{:>6} {:>14} {:>16} {:>12} {:>12} {:>9}",
        "batch", "flat_KV_MiB", "cascade_KV_MiB", "flat_us", "cascade_us", "speedup"
    );
    let arch = GpuArch::a100();
    for batch in [2usize, 4, 8, 16] {
        let p = CascadeProblem::new(
            32,
            vec![65_536 + 2_048; batch],
            64,
            vec![PrefixGroup {
                prefix_len: 65_536,
                members: (0..batch as u32).collect(),
            }],
        )?;
        let r = simulate_cascade(&p, &arch);
        let flat = simulate(&p.baseline_problem(), Strategy::StreamK, &arch);
        println!(
            "{:>6} {:>14.1} {:>16.1} {:>12.1} {:>12.1} {:>8.2}x",
            batch,
            r.baseline_kv_bytes / (1024.0 * 1024.0),
            r.kv_bytes / (1024.0 * 1024.0),
            flat.latency_us,
            r.latency_us,
            flat.latency_us / r.latency_us
        );
    }

    // --- part 2: radix index + copy-on-write paged KV, no PJRT needed ----
    println!("\n== radix prefix cache over the paged KV store (8 users, one system prompt) ==");
    let (layers, heads, dh, page_tokens) = (2usize, 4usize, 16usize, 16usize);
    let mut cache = PagedKvCache::new(layers, heads, dh, page_tokens, 128);
    let mut index = RadixPrefixIndex::new(page_tokens);
    let mut metrics = Metrics::default();
    let mut rng = Rng::new(7);

    let system: Vec<i32> = (0..64).map(|_| rng.range(0, 512) as i32).collect();
    for user in 0..8u64 {
        // Each user: the shared 64-token system prompt + a private tail.
        let tail_len = 5 + user as usize % 7;
        let mut prompt = system.clone();
        prompt.extend((0..tail_len).map(|_| rng.range(0, 512) as i32));

        metrics.prefix.lookups += 1;
        let m = index.lookup(&prompt);
        let suffix = prompt.len() - m.tokens;
        let n = layers * heads * suffix * dh;
        let (k, v) = (rng.normal_vec(n), rng.normal_vec(n));
        if m.tokens > 0 {
            metrics.prefix.hits += 1;
            metrics.prefix.tokens_matched += m.tokens;
            metrics.prefix.pages_shared += m.pages.len();
            metrics.prefix.kv_bytes_deduped +=
                (m.pages.len() * cache.page_bytes()) as u64;
            cache.insert_seq_shared(user, &m.pages, &k, &v, suffix)?;
        } else {
            cache.insert_seq(user, &k, &v, prompt.len())?;
        }
        // Register this prompt's full pages for future sharers.
        let pages = cache.seq_pages(user).unwrap().to_vec();
        for p in index.insert(&prompt, &pages) {
            cache.retain_page(p)?;
        }
        println!(
            "  user {user}: prompt {} tokens, {} from cache, cache now {}/{} pages used",
            prompt.len(),
            m.tokens,
            cache.used_pages(),
            cache.total_pages()
        );
    }
    println!(
        "\n  without sharing these prompts would need {} pages; with the radix cache: {}",
        8 * cache.pages_for(64 + 5),
        cache.used_pages()
    );
    print!("\n{}", metrics.report());

    // --- part 3: the real engine, when artifacts are built ---------------
    println!("\n== serving engine with a shared system prompt (PJRT) ==");
    let Ok(manifest) = Manifest::load(Manifest::default_dir()) else {
        println!("  skipped: artifacts not built (run `make artifacts`)");
        return Ok(());
    };
    let runtime = Rc::new(Runtime::cpu()?);
    let mut engine = Engine::new(&runtime, &manifest, EngineConfig::default())?;
    let sys_len = (engine.prefill_bucket() / 2).max(1);
    let system: Vec<i32> = (0..sys_len).map(|_| rng.range(0, 512) as i32).collect();
    let mut finished = Vec::new();
    // Warm the radix index with one request, then serve the rest — they
    // all share the system prompt's pages.
    for wave in 0..2 {
        for _ in 0..if wave == 0 { 1 } else { 5 } {
            let mut prompt = system.clone();
            let tail = rng.urange(1, engine.prefill_bucket() - sys_len + 1);
            prompt.extend((0..tail).map(|_| rng.range(0, 512) as i32));
            engine.submit(prompt, 8)?;
        }
        finished.extend(engine.run_until_idle()?);
    }
    for f in &finished {
        println!(
            "  req {}: prompt {} -> {} tokens ({:?})",
            f.id,
            f.prompt_len,
            f.output.len(),
            f.reason
        );
    }
    println!("\n{}", engine.metrics.report());
    if sys_len >= engine.config.page_tokens {
        assert!(
            engine.metrics.prefix.hit_rate() > 0.0,
            "requests after the first admission wave must hit the prefix cache"
        );
    }
    Ok(())
}
