//! Speculative decoding end to end on the host pipeline (no artifacts
//! needed): draft a block, verify every position in one multi-query
//! pass, commit 1..=k+1 tokens — with the committed stream proven
//! bit-identical to plain sequential decoding, and the modeled GPU
//! speedup for the same shapes.
//!
//! ```sh
//! cargo run --release --example speculative
//! ```

use lean_attention::model::ModelConfig;
use lean_attention::sampling::{seq_rng, SamplingParams};
use lean_attention::sim::{simulate_spec_decode, GpuArch, SpecDecodeCase};
use lean_attention::spec::{
    sequential_generate, spec_generate, ModelDrafter, NGramDrafter, SyntheticModel,
};

fn main() {
    let vocab = 64;
    let seed = 7u64;
    // A repetitive workload: the shape where self-drafting shines
    // (retrieval answers, code, templated text).
    let prompt: Vec<i32> = (0..48).map(|i| i % 12).collect();
    let max_new = 96;
    let params = SamplingParams::greedy();
    let target = SyntheticModel::new(vocab, seed, 6.0);

    let mut rng = seq_rng(seed, 1);
    let sequential = sequential_generate(&target, &prompt, max_new, &params, &mut rng);
    println!(
        "sequential oracle: {max_new} tokens in {max_new} model steps (one per token)\n"
    );

    println!(
        "{:<8} {:>3} {:>8} {:>12} {:>10} {:>10}",
        "drafter", "k", "passes", "tokens/pass", "accepted", "identical"
    );
    for k in [1usize, 2, 4, 8] {
        // Self-drafting: suffix lookup over the sequence's own history.
        let mut ngram = NGramDrafter::default();
        let mut rng = seq_rng(seed, 1);
        let run = spec_generate(&target, &mut ngram, k, &prompt, max_new, &params, &mut rng);
        println!(
            "{:<8} {:>3} {:>8} {:>12.2} {:>9.0}% {:>10}",
            "ngram",
            k,
            run.stats.verify_passes,
            run.stats.tokens_per_pass(),
            run.stats.acceptance_rate() * 100.0,
            run.tokens == sequential,
        );
    }

    // The smaller-model drafter, configured from a ModelConfig: a
    // shallower synthetic model proposes, the target verifies.
    let small = ModelConfig::bench_d64(2);
    let mut drafter = ModelDrafter::from_config(&small, seed ^ 0x51);
    let mut rng = seq_rng(seed, 1);
    let run = spec_generate(&target, &mut drafter, 4, &prompt, max_new, &params, &mut rng);
    println!(
        "{:<8} {:>3} {:>8} {:>12.2} {:>9.0}% {:>10}",
        "model",
        4,
        run.stats.verify_passes,
        run.stats.tokens_per_pass(),
        run.stats.acceptance_rate() * 100.0,
        run.tokens == sequential,
    );

    // Modeled GPU economics: one k-query verify pass streams the cached
    // context once; sequential streams it once per token.
    println!("\nmodeled on A100 (32 heads x d128, 64k context):");
    println!(
        "{:>4} {:>10} {:>14} {:>12} {:>10}",
        "k", "accept", "tokens/pass", "KV saved", "speedup"
    );
    let arch = GpuArch::a100();
    for (k, acceptance) in [(2usize, 0.6), (4, 0.8), (8, 0.8), (8, 0.95)] {
        let case = SpecDecodeCase {
            heads: 32,
            head_dim: 128,
            ctx: 65_536,
            k,
            acceptance,
        };
        let r = simulate_spec_decode(&case, &arch);
        println!(
            "{:>4} {:>9.0}% {:>14.2} {:>11.0}% {:>9.2}x",
            k,
            acceptance * 100.0,
            r.tokens_per_pass,
            r.bytes_saved_fraction() * 100.0,
            r.speedup(),
        );
    }
}
