//! End-to-end serving driver (the repo's full-system validation run,
//! recorded in EXPERIMENTS.md §E2E).
//!
//! Loads the `small` transformer (real weights from the AOT blob), serves
//! a batched workload of synthetic requests through the full stack —
//! router → continuous batcher → batched prefill → paged KV cache →
//! per-step decode through the PJRT artifact (whose attention is the L1
//! LeanAttention Pallas kernel) → greedy sampling — and reports
//! latency/throughput plus the per-step A100 hardware projection.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_decode -- [requests] [max_new]
//! ```

use std::rc::Rc;
use std::time::Instant;

use lean_attention::coordinator::{Engine, EngineConfig};
use lean_attention::runtime::{Manifest, Runtime};
use lean_attention::util::rng::Rng;
use lean_attention::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(12);
    let max_new: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);

    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Manifest::load(Manifest::default_dir())?;
    let mut engine = Engine::new(
        &runtime,
        &manifest,
        EngineConfig {
            model: "small".into(),
            cache_pages: 1024,
            page_tokens: 16,
            project_hardware: true,
            ..EngineConfig::default()
        },
    )?;
    println!(
        "model=small ({} layers x {} heads x d{}), engine batch {}, ctx bucket {}",
        4, 4, 64,
        engine.batch_size(),
        engine.ctx_bucket()
    );

    // Synthetic workload: mixed prompt lengths, fixed generation budget.
    let mut rng = Rng::new(42);
    let t0 = Instant::now();
    for _ in 0..n_requests {
        let len = rng.urange(4, engine.prefill_bucket() + 1);
        let prompt: Vec<i32> = (0..len).map(|_| rng.range(0, 2048) as i32).collect();
        engine.submit(prompt, max_new)?;
    }
    let finished = engine.run_until_idle()?;
    let wall_s = t0.elapsed().as_secs_f64();

    // --- report -----------------------------------------------------------
    println!("\n== serve_decode results ==");
    println!(
        "{n_requests} requests, {} tokens generated in {wall_s:.2}s wall ({:.1} tok/s aggregate)",
        engine.metrics.tokens_generated,
        engine.metrics.tokens_generated as f64 / wall_s
    );

    let total: Vec<f64> = finished.iter().map(|f| f.total_s() * 1e3).collect();
    let tps: Vec<f64> = finished.iter().map(|f| f.decode_tps()).collect();
    let ts = Summary::of(&total);
    println!(
        "request latency ms: mean {:.0}  p50 {:.0}  p99 {:.0}  max {:.0}",
        ts.mean, ts.p50, ts.p99, ts.max
    );
    println!(
        "per-request decode throughput: mean {:.1} tok/s",
        tps.iter().sum::<f64>() / tps.len() as f64
    );
    println!();
    println!("{}", engine.metrics.report());

    assert_eq!(finished.len(), n_requests, "all requests completed");
    Ok(())
}
