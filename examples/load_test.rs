//! Trace-driven load test: Poisson arrivals replayed open-loop against
//! the serving engine at several offered loads, reporting TTFT and
//! end-to-end latency percentiles — the deployment-facing view of the
//! decode-phase scheduling this repo reproduces.
//!
//! ```sh
//! make artifacts && cargo run --release --example load_test
//! ```

use std::rc::Rc;

use lean_attention::bench_harness::trace::{replay, TraceSpec};
use lean_attention::coordinator::{Engine, EngineConfig};
use lean_attention::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Manifest::load(Manifest::default_dir())?;

    println!("== load test: tiny model, Poisson arrivals ==\n");
    for &(label, gap) in &[("light load", 8.0f64), ("moderate", 3.0), ("saturating", 0.5)] {
        // fresh engine per load level so queues don't carry over
        let mut engine = Engine::new(
            &runtime,
            &manifest,
            EngineConfig { model: "tiny".into(), ..Default::default() },
        )?;
        let spec = TraceSpec {
            requests: 16,
            mean_gap_steps: gap,
            poisson: true,
            prompt_min: 2,
            prompt_max: engine.prefill_bucket(),
            new_min: 2,
            new_max: 12,
            seed: 99,
        };
        let report = replay(&mut engine, &spec)?;
        println!("-- {label} (mean gap {gap} steps) --");
        println!("{}\n", report.render());
        if let Some(speedup) = engine.metrics.projected_speedup() {
            println!("   A100 projection for this batch mix: LA {speedup:.2}x over FD\n");
        }
    }
    Ok(())
}
