//! Trace-driven load test: Poisson arrivals replayed open-loop against
//! the serving engine at several offered loads, reporting the serving
//! SLO view — TTFT and end-to-end percentiles from the per-request
//! lifecycle timelines, goodput, and SLO attainment at a `--slo-ms`
//! target — the deployment-facing view of the decode-phase scheduling
//! this repo reproduces (ROADMAP open item 1's load generator).
//!
//! ```sh
//! make artifacts && cargo run --release --example load_test -- \
//!     --requests 16 --slo-ms 50 [--fixed] [--seed 99] \
//!     [--trace-capacity 4096 --trace-out /tmp/leanattn.trace.json] \
//!     [--metrics-out /tmp/leanattn.prom]
//! ```
//!
//! Each load level runs on a fresh engine (queues don't carry over).
//! `--metrics-out` writes the last level's metrics snapshot (`.prom` →
//! Prometheus text exposition, anything else → versioned JSON);
//! `--trace-capacity N --trace-out` writes its Chrome trace-event
//! export for `chrome://tracing` / `ui.perfetto.dev`.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use lean_attention::bench_harness::trace::{replay, TraceSpec};
use lean_attention::coordinator::{Engine, EngineConfig};
use lean_attention::runtime::{Manifest, Runtime};

fn main() -> Result<()> {
    let flags = parse_flags();
    let usize_of = |k: &str, d: usize| -> usize {
        flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    let f64_of =
        |k: &str, d: f64| -> f64 { flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d) };
    let slo_ms = f64_of("slo-ms", 50.0);
    let requests = usize_of("requests", 16);
    let seed = usize_of("seed", 99) as u64;
    let trace_capacity = usize_of("trace-capacity", 0);

    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Manifest::load(Manifest::default_dir())
        .context("load artifacts (run `make artifacts`)")?;

    println!("== load test: tiny model, {requests} requests/level, SLO {slo_ms} ms ==\n");
    let mut last: Option<Engine> = None;
    for &(label, gap) in &[("light load", 8.0f64), ("moderate", 3.0), ("saturating", 0.5)] {
        // fresh engine per load level so queues don't carry over
        let mut engine = Engine::new(
            &runtime,
            &manifest,
            EngineConfig {
                model: "tiny".into(),
                seed,
                trace_capacity,
                ..Default::default()
            },
        )?;
        let spec = TraceSpec {
            requests,
            mean_gap_steps: gap,
            poisson: !flags.contains_key("fixed"),
            prompt_min: 2,
            prompt_max: engine.prefill_bucket(),
            new_min: 2,
            new_max: usize_of("max-new", 12),
            seed,
        };
        let report = replay(&mut engine, &spec)?;
        println!("-- {label} (mean gap {gap} steps) --");
        println!("{}\n", report.render());
        // The engine recorded one lifecycle timeline per finished
        // request; fold them into the SLO attainment report.
        println!("{}", engine.timelines.slo_report(slo_ms, report.wall_s).render());
        if let Some(speedup) = engine.metrics.projected_speedup() {
            println!("   A100 projection for this batch mix: LA {speedup:.2}x over FD\n");
        }
        last = Some(engine);
    }

    // Observability exports cover the last (most loaded) level.
    let engine = last.expect("at least one load level ran");
    if let Some(path) = flags.get("metrics-out") {
        let snap = engine.snapshot();
        let text = if path.ends_with(".prom") {
            snap.to_prometheus()
        } else {
            snap.to_json().to_string()
        };
        std::fs::write(path, &text)
            .with_context(|| format!("write metrics snapshot to {path}"))?;
        println!("metrics snapshot: {} series -> {path}", snap.names().len());
    }
    if let Some(path) = flags.get("trace-out") {
        let trace = engine.tracer.export_chrome_trace();
        std::fs::write(path, trace.to_string())
            .with_context(|| format!("write chrome trace to {path}"))?;
        println!(
            "chrome trace: {} events -> {path} ({} dropped to ring overflow)",
            engine.tracer.len(),
            engine.tracer.dropped()
        );
    }
    Ok(())
}

/// `--key value` pairs; a `--flag` followed by another `--` (or nothing)
/// is a boolean. Mirrors the CLI's hand-rolled parser (clap is not in
/// the offline crate cache).
fn parse_flags() -> HashMap<String, String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        if let Some(key) = argv[i].strip_prefix("--") {
            match argv.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(key.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    flags
}
