//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT-compiled LeanAttention kernel artifacts (Pallas → HLO
//!    text → PJRT).
//! 2. Run exact decode attention for a small batch, and the same problem
//!    through the stream-K partial path with the softmax re-scaling
//!    reduction in Rust.
//! 3. Check both against the Rust oracle, then project the schedule onto
//!    an A100 to see the paper's speedup.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use lean_attention::attention::attention_host;
use lean_attention::partition::plan::{build_plan, DecodeProblem, Strategy};
use lean_attention::runtime::attention_exec::AttentionProblem;
use lean_attention::runtime::{AttentionExecutor, Manifest, Runtime};
use lean_attention::sim::schedule::simulate;
use lean_attention::sim::GpuArch;
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::max_abs_err;

fn main() -> anyhow::Result<()> {
    // --- load the runtime + artifacts -----------------------------------
    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Rc::new(Manifest::load(Manifest::default_dir())?);
    println!("PJRT platform: {}", runtime.platform());
    let exec = AttentionExecutor::new(runtime, manifest);

    // --- a decode-attention problem: 6 (batch*head) groups, ragged ------
    let (g, n, d) = (6usize, 1024usize, 64usize);
    let mut rng = Rng::new(7);
    let q = rng.normal_vec(g * d);
    let k = rng.normal_vec(g * n * d);
    let v = rng.normal_vec(g * n * d);
    let lens: Vec<u32> = vec![1024, 700, 64, 1, 333, 512];
    let problem = AttentionProblem { q: &q, k: &k, v: &v, lens: &lens, g, n, d };

    // --- path 1: fused kernel artifact -----------------------------------
    let (o_full, _lse) = exec.full(&problem)?;

    // --- path 2: stream-K partials + Rust softmax re-scaling reduce -----
    let decode = DecodeProblem { heads: 1, head_dim: d, ctx_lens: lens.clone(), tile: 256 };
    let plan = build_plan(&decode, Strategy::StreamK, 13);
    plan.validate(&decode)?;
    let (o_lean, _) = exec.lean(&problem, &plan)?;

    // --- oracle check -----------------------------------------------------
    let oracle = attention_host(&q, &k, &v, g, n, d, &lens);
    println!("fused-kernel  max err vs oracle: {:.2e}", max_abs_err(&o_full, &oracle));
    println!("stream-K path max err vs oracle: {:.2e}", max_abs_err(&o_lean, &oracle));
    assert!(max_abs_err(&o_full, &oracle) < 3e-4);
    assert!(max_abs_err(&o_lean, &oracle) < 3e-4);
    println!("exactness: stream-K partials + re-scaling reduce == fused attention ✓");

    // --- project the schedule onto an A100 -------------------------------
    let arch = GpuArch::a100();
    let big = DecodeProblem::uniform(4, 32, 262_144, 64);
    let fd = simulate(&big, Strategy::fixed_split_auto(&big, arch.num_sms), &arch);
    let la = simulate(&big, Strategy::StreamK, &arch);
    println!(
        "\nA100 projection (batch 4 x 32 heads x 256k ctx):\n  FlashDecoding {:.0}us ({:.0}% occupancy) vs LeanAttention {:.0}us ({:.0}% occupancy) -> {:.2}x",
        fd.latency_us,
        fd.occupancy * 100.0,
        la.latency_us,
        la.occupancy * 100.0,
        fd.latency_us / la.latency_us
    );
    Ok(())
}
