//! Lean ragged batching (§IV-C, Fig 10): serve a heterogeneous batch of
//! context lengths and show (a) the engine handling raggedness end to end
//! with real numerics, and (b) why stream-K's equal-LeanTile split beats
//! fixed-split as heterogeneity grows.
//!
//! ```sh
//! make artifacts && cargo run --release --example ragged_batch
//! ```

use std::rc::Rc;

use lean_attention::bench_harness::workload::ragged_batch;
use lean_attention::coordinator::{Engine, EngineConfig};
use lean_attention::partition::plan::{build_plan, Strategy};
use lean_attention::runtime::{Manifest, Runtime};
use lean_attention::sim::schedule::simulate;
use lean_attention::sim::GpuArch;
use lean_attention::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- part 1: heterogeneity sweep on the A100 schedule model ---------
    println!("== stream-K vs fixed-split under batch heterogeneity (A100) ==");
    println!(
        "{:>14} {:>16} {:>12} {:>12} {:>9}",
        "ctx_ratio%", "lens(example)", "FD_us", "LA_us", "LA/FD"
    );
    let arch = GpuArch::a100();
    for &ratio in &[1.0, 0.8, 0.6, 0.4, 0.2] {
        let p = ragged_batch(8, 32, 65536, ratio, 11);
        let fd = simulate(&p, Strategy::fixed_split_auto(&p, arch.num_sms), &arch);
        let la = simulate(&p, Strategy::StreamK, &arch);
        let mut lens: Vec<u32> = p.ctx_lens.clone();
        lens.sort_unstable();
        println!(
            "{:>13.0}% {:>16} {:>12.0} {:>12.0} {:>8.2}x",
            p.batch_context_ratio() * 100.0,
            format!("{}..{}", lens[0], lens[lens.len() - 1]),
            fd.latency_us,
            la.latency_us,
            fd.latency_us / la.latency_us
        );
    }

    // --- part 2: ragged load balance in tiles ----------------------------
    println!("\n== LeanTile loads per CTA (ragged batch, 16 CTA slots) ==");
    let p = ragged_batch(4, 2, 8192, 0.4, 3);
    let lean = build_plan(&p, Strategy::StreamK, 16);
    let fd = build_plan(&p, Strategy::fixed_split_auto(&p, 16), 16);
    println!("context lengths: {:?}", p.ctx_lens);
    println!("stream-K tiles/CTA:    {:?}", lean.tiles_per_cta());
    println!("fixed-split tiles/CTA: {:?}", fd.tiles_per_cta());
    println!(
        "imbalance (max/mean): stream-K {:.3} vs fixed-split {:.3}",
        lean.imbalance(),
        fd.imbalance()
    );

    // --- part 3: ragged batch through the real engine --------------------
    println!("\n== ragged batch through the serving engine (PJRT, real numerics) ==");
    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Manifest::load(Manifest::default_dir())?;
    let mut engine = Engine::new(&runtime, &manifest, EngineConfig::default())?;
    let mut rng = Rng::new(5);
    let p_bucket = engine.prefill_bucket();
    // deliberately heterogeneous prompts: 1 token .. full bucket
    for len in [1usize, p_bucket / 8, p_bucket / 2, p_bucket] {
        let prompt: Vec<i32> =
            (0..len.max(1)).map(|_| rng.range(0, 512) as i32).collect();
        engine.submit(prompt, 8)?;
    }
    let finished = engine.run_until_idle()?;
    for f in &finished {
        println!(
            "  req {}: prompt {} -> {} tokens ({:?})",
            f.id,
            f.prompt_len,
            f.output.len(),
            f.reason
        );
    }
    println!("\n{}", engine.metrics.report());
    Ok(())
}
