//! Attention-operation comparison on real tensors through PJRT:
//! the fused kernel vs the stream-K partial path under each partitioning
//! strategy, with wall-clock on this CPU and the A100 projection side by
//! side. (CPU wall-clock is NOT a GPU proxy — it validates plumbing cost
//! and exactness; the projection column is the paper-relevant number.)
//!
//! ```sh
//! make artifacts && cargo run --release --example lean_vs_flash
//! ```

use std::rc::Rc;
use std::time::Instant;

use lean_attention::attention::attention_host;
use lean_attention::partition::plan::{build_plan, DecodeProblem, Strategy};
use lean_attention::runtime::attention_exec::AttentionProblem;
use lean_attention::runtime::{AttentionExecutor, Manifest, Runtime};
use lean_attention::sim::schedule::simulate;
use lean_attention::sim::GpuArch;
use lean_attention::util::rng::Rng;
use lean_attention::util::testing::max_abs_err;

fn main() -> anyhow::Result<()> {
    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Rc::new(Manifest::load(Manifest::default_dir())?);
    let exec = AttentionExecutor::new(runtime, manifest);
    let arch = GpuArch::a100();

    let (g, n, d) = (8usize, 4096usize, 64usize);
    let mut rng = Rng::new(1);
    let q = rng.normal_vec(g * d);
    let k = rng.normal_vec(g * n * d);
    let v = rng.normal_vec(g * n * d);
    let lens: Vec<u32> = (0..g).map(|_| rng.range(1, n as u64 + 1) as u32).collect();
    let ap = AttentionProblem { q: &q, k: &k, v: &v, lens: &lens, g, n, d };
    let oracle = attention_host(&q, &k, &v, g, n, d, &lens);

    println!("decode attention: g={g} groups, ctx<=?{n}, d={d} (ragged lens)");
    println!(
        "{:<22} {:>12} {:>12} {:>14} {:>12}",
        "path", "cpu_ms", "max_err", "a100_proj_us", "occupancy"
    );

    // fused kernel
    let t0 = Instant::now();
    let (o_full, _) = exec.full(&ap)?;
    let fused_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "{:<22} {:>12.1} {:>12.2e} {:>14} {:>12}",
        "fused kernel",
        fused_ms,
        max_abs_err(&o_full, &oracle),
        "-",
        "-"
    );

    // stream-K and baselines through the partial path
    let problem = DecodeProblem { heads: 1, head_dim: d, ctx_lens: lens.clone(), tile: 256 };
    for strategy in [
        Strategy::Dense,
        Strategy::fixed_split_auto(&problem, arch.num_sms),
        Strategy::StreamK,
    ] {
        let plan = build_plan(&problem, strategy, arch.sm_slots());
        plan.validate(&problem)?;
        let t0 = Instant::now();
        let (o, _) = exec.lean(&ap, &plan)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let sim = simulate(&problem, strategy, &arch);
        println!(
            "{:<22} {:>12.1} {:>12.2e} {:>14.1} {:>11.0}%",
            format!("partials/{}", strategy.name()),
            ms,
            max_abs_err(&o, &oracle),
            sim.latency_us,
            sim.occupancy * 100.0
        );
    }

    println!("\nall paths compute the same exact attention; the projection column");
    println!("shows why the stream-K placement wins on real hardware.");
    Ok(())
}
