//! Regenerate every table and figure of the paper's evaluation section
//! (Table I, Figs 1-3, 7-13 and the §VI 1000-sample aggregate) from the
//! GPU-schedule simulator. Results print as aligned tables and are
//! persisted under `target/figures/*.{txt,json}`.
//!
//! ```sh
//! cargo run --release --example paper_figures            # everything
//! cargo run --release --example paper_figures -- fig07   # one figure
//! cargo run --release --example paper_figures -- sweep 2000
//! ```

use lean_attention::bench_harness::figures;
use lean_attention::sim::GpuArch;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let which = args.first().map(String::as_str).unwrap_or("all");
    let all = which == "all";

    if all || which == "table1" {
        figures::table1().emit("table1");
    }
    if all || which == "fig01" {
        println!("{}", figures::fig01_schedule());
    }
    if all || which == "fig02" {
        figures::fig02_timeshare().emit("fig02");
    }
    if all || which == "fig03" {
        figures::fig03_occupancy().emit("fig03");
    }
    if all || which == "fig07" {
        for (i, t) in figures::fig07_a100().iter().enumerate() {
            t.emit(&format!("fig07{}", ['a', 'b', 'c'][i]));
        }
    }
    if all || which == "fig08" {
        for (i, t) in figures::fig08_h100().iter().enumerate() {
            t.emit(&format!("fig08{}", ['a', 'b', 'c'][i]));
        }
    }
    if all || which == "fig09" {
        for (i, t) in figures::fig09_multigpu().iter().enumerate() {
            t.emit(&format!("fig09{}", ['a', 'b', 'c'][i]));
        }
    }
    if all || which == "fig10" {
        figures::fig10_ragged().emit("fig10");
    }
    if all || which == "fig11" {
        figures::fig11_headdim128().emit("fig11");
    }
    if all || which == "fig12" {
        figures::fig12_e2e().emit("fig12");
    }
    if all || which == "fig13" {
        figures::fig13_energy().emit("fig13");
    }
    if all || which == "sweep" {
        let samples = args
            .get(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or(if all { 1000 } else { 1000 });
        figures::sweep_aggregate(samples, &GpuArch::a100()).emit("sweep_a100");
        figures::sweep_aggregate(samples, &GpuArch::h100()).emit("sweep_h100");
    }
}
