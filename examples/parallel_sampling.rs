//! Parallel sampling: best-of-n over zero-copy forks of one prompt.
//!
//! Best-of-n, beam search and speculative drafts fork a sequence into
//! siblings that share their *entire* history up to the fork point —
//! the highest-multiplicity KV sharing real serving produces. Three
//! wins, demonstrated in three parts:
//!
//! 1. **Bandwidth (model)**: a fork family's shared history streams
//!    once per group per decode step instead of once per sibling
//!    (part 1, `sim::simulate_fork_decode`).
//! 2. **Storage + gather (host, no artifacts)**: forking on the COW
//!    paged KV cache allocates zero pages; divergence costs at most one
//!    copy-on-write clone per sibling; the sibling-cascade gather reads
//!    strictly fewer bytes than flat (part 2,
//!    `bench_harness::compare_sampling`).
//! 3. **Serving**: the engine's `fork` + the `BestOfN` controller pick
//!    the highest-logprob candidate, deterministically under a fixed
//!    seed (part 3, requires `make artifacts`; skipped gracefully).
//!
//! ```sh
//! cargo run --release --example parallel_sampling
//! ```

use std::rc::Rc;

use lean_attention::bench_harness::{compare_sampling, SamplingCase};
use lean_attention::coordinator::{Engine, EngineConfig};
use lean_attention::runtime::{Manifest, Runtime};
use lean_attention::sampling::{BestOfN, SamplingParams};
use lean_attention::sim::{simulate_fork_decode, ForkDecodeCase, GpuArch};
use lean_attention::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- part 1: modeled fork-family decode traffic on the A100 ----------
    println!("== fork-family decode vs flat (A100, 8 heads, 16k shared history, 64 steps) ==");
    println!(
        "{:>9} {:>13} {:>16} {:>12} {:>9}",
        "siblings", "flat_KV_MiB", "cascade_KV_MiB", "bytes_saved", "speedup"
    );
    let arch = GpuArch::a100();
    for siblings in [1usize, 2, 4, 8] {
        let r = simulate_fork_decode(
            &ForkDecodeCase {
                heads: 8,
                head_dim: 64,
                siblings,
                history: 16_384,
                decode_steps: 64,
            },
            &arch,
        );
        println!(
            "{siblings:>9} {:>13.1} {:>16.1} {:>11.1}% {:>8.2}x",
            r.flat_kv_bytes / (1024.0 * 1024.0),
            r.cascade_kv_bytes / (1024.0 * 1024.0),
            r.bytes_saved_fraction() * 100.0,
            r.speedup()
        );
    }

    // --- part 2: real forks on the COW paged KV cache (no PJRT) ----------
    println!("\n== zero-copy forks + sibling-cascade gather (host) ==");
    let case = SamplingCase::default_case();
    let c = compare_sampling(case, 5, 42)?;
    println!(
        "  {} siblings forked over a {}-token history: {} pages allocated at fork, \
         {} COW clones while decoding {} divergent tokens each",
        case.siblings, case.history, c.fork_fresh_pages, c.cow_copies, case.suffix
    );
    println!(
        "  gather per decode step: flat {:.1} KiB vs sibling-cascade {:.1} KiB \
         ({:.1}% saved)",
        c.flat_gather_bytes as f64 / 1024.0,
        c.shared_gather_bytes as f64 / 1024.0,
        c.bytes_saved_fraction() * 100.0
    );
    assert_eq!(c.fork_fresh_pages, 0, "forking is refcount-only");
    assert!(c.shared_gather_bytes < c.flat_gather_bytes);

    // --- part 3: best-of-n on the serving engine (PJRT artifacts) --------
    println!("\n== best-of-4 serving (PJRT) ==");
    let Ok(manifest) = Manifest::load(Manifest::default_dir()) else {
        println!("  skipped: artifacts not built (run `make artifacts`)");
        return Ok(());
    };
    let runtime = Rc::new(Runtime::cpu()?);
    let params = SamplingParams {
        temperature: 0.8,
        top_k: 40,
        top_p: 0.95,
        repetition_penalty: 1.1,
    };
    let mut engine = Engine::new(
        &runtime,
        &manifest,
        EngineConfig { sampling: params.clone(), seed: 7, ..EngineConfig::default() },
    )?;
    let n = 4.min(engine.batch_size());
    let mut rng = Rng::new(3);
    let prompt: Vec<i32> = (0..engine.prefill_bucket().min(24))
        .map(|_| rng.range(0, 512) as i32)
        .collect();
    let outcome = BestOfN { n, max_new: 12, params }.run(&mut engine, prompt)?;
    for (rank, cand) in outcome.candidates.iter().enumerate() {
        println!(
            "  {} candidate {}: {} tokens, cum logprob {:>8.3}{}",
            if rank == 0 { "*" } else { " " },
            cand.finished.id,
            cand.finished.output.len(),
            cand.score,
            cand.finished
                .parent
                .map(|p| format!(" (forked off {p})"))
                .unwrap_or_default(),
        );
    }
    println!("\n{}", engine.metrics.report());
    assert_eq!(outcome.candidates.len(), n, "every candidate finished");
    Ok(())
}
